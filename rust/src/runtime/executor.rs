//! The executor thread: sole owner of the PJRT client, serving eval jobs
//! over a channel.  [`ExecutorHandle`] is `Clone + Send + Sync`, so the
//! samplers (which require `Sync` drifts) and the multi-threaded
//! coordinator can all share one device owner.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::manifest::Manifest;
use crate::metrics::Metrics;

type Resp<T> = Sender<Result<T>>;

enum Job {
    Eps { level: usize, x: Vec<f32>, t: f64, pallas: bool, resp: Resp<Vec<f32>> },
    EpsJvp { level: usize, x: Vec<f32>, t: f64, v: Vec<f32>, resp: Resp<(Vec<f32>, Vec<f32>)> },
    Combine {
        y: Vec<f32>,
        deltas: Vec<f32>,
        coeffs: Vec<f32>,
        z: Vec<f32>,
        eta: f64,
        sigma: f64,
        pallas: bool,
        resp: Resp<Vec<f32>>,
    },
    MeasureCosts { reps: usize, resp: Resp<Vec<f64>> },
    Warmup { bucket: usize, resp: Resp<()> },
    ExecStats { resp: Resp<(u64, u64)> },
    Stop,
}

/// Cloneable, thread-safe handle to the executor thread.
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: Sender<Job>,
    manifest: Manifest,
}

// Sender<Job> is Send+Sync (Job: Send); Manifest is plain data.
// ExecutorHandle derives both automatically.

/// Spawn the executor thread over `manifest`'s artifacts.  Returns the
/// handle and the join handle (join after dropping all handles/Stop).
pub fn spawn_executor(
    manifest: Manifest,
    metrics: Option<Metrics>,
) -> Result<(ExecutorHandle, JoinHandle<()>)> {
    let (tx, rx) = channel::<Job>();
    let handle_manifest = manifest.clone();
    let join = std::thread::Builder::new()
        .name("pjrt-executor".to_string())
        .spawn(move || {
            let mut engine = match Engine::new(manifest) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[executor] failed to start engine: {e:#}");
                    // Drain jobs with errors so callers unblock.
                    for job in rx {
                        match job {
                            Job::Eps { resp, .. } => {
                                let _ = resp.send(Err(anyhow!("engine unavailable")));
                            }
                            Job::EpsJvp { resp, .. } => {
                                let _ = resp.send(Err(anyhow!("engine unavailable")));
                            }
                            Job::Combine { resp, .. } => {
                                let _ = resp.send(Err(anyhow!("engine unavailable")));
                            }
                            Job::MeasureCosts { resp, .. } => {
                                let _ = resp.send(Err(anyhow!("engine unavailable")));
                            }
                            Job::Warmup { resp, .. } => {
                                let _ = resp.send(Err(anyhow!("engine unavailable")));
                            }
                            Job::ExecStats { resp } => {
                                let _ = resp.send(Err(anyhow!("engine unavailable")));
                            }
                            Job::Stop => break,
                        }
                    }
                    return;
                }
            };
            for job in rx {
                match job {
                    Job::Eps { level, x, t, pallas, resp } => {
                        let t0 = std::time::Instant::now();
                        let r = engine.eps(level, &x, t, pallas);
                        if let Some(m) = &metrics {
                            m.execute_latency.record(t0.elapsed());
                        }
                        let _ = resp.send(r);
                    }
                    Job::EpsJvp { level, x, t, v, resp } => {
                        let r = engine.eps_jvp(level, &x, t, &v);
                        let _ = resp.send(r);
                    }
                    Job::Combine { y, deltas, coeffs, z, eta, sigma, pallas, resp } => {
                        let r = engine.combine(&y, &deltas, &coeffs, &z, eta, sigma, pallas);
                        let _ = resp.send(r);
                    }
                    Job::MeasureCosts { reps, resp } => {
                        let _ = resp.send(engine.measure_costs(reps));
                    }
                    Job::Warmup { bucket, resp } => {
                        let _ = resp.send(engine.warmup(bucket));
                    }
                    Job::ExecStats { resp } => {
                        let _ = resp.send(Ok((engine.exec_calls, engine.exec_ns)));
                    }
                    Job::Stop => break,
                }
            }
        })?;
    Ok((ExecutorHandle { tx, manifest: handle_manifest }, join))
}

impl ExecutorHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call<T>(&self, job: Job, rx: std::sync::mpsc::Receiver<Result<T>>) -> Result<T> {
        self.tx.send(job).map_err(|_| anyhow!("executor thread gone"))?;
        rx.recv().map_err(|_| anyhow!("executor dropped response"))?
    }

    /// Evaluate a level's eps network on a flattened `[n, dim]` batch.
    pub fn eps(&self, level: usize, x: &[f32], t: f64) -> Result<Vec<f32>> {
        let (resp, rx) = channel();
        self.call(Job::Eps { level, x: x.to_vec(), t, pallas: false, resp }, rx)
    }

    /// Same through the Pallas-flavour parity artifact.
    pub fn eps_pallas(&self, level: usize, x: &[f32], t: f64) -> Result<Vec<f32>> {
        let (resp, rx) = channel();
        self.call(Job::Eps { level, x: x.to_vec(), t, pallas: true, resp }, rx)
    }

    /// Evaluate (eps, ∂eps·v).
    pub fn eps_jvp(&self, level: usize, x: &[f32], t: f64, v: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let (resp, rx) = channel();
        self.call(Job::EpsJvp { level, x: x.to_vec(), t, v: v.to_vec(), resp }, rx)
    }

    /// Fused ML-EM combine step (see `engine::Engine::combine`).
    #[allow(clippy::too_many_arguments)]
    pub fn combine(
        &self,
        y: &[f32],
        deltas: &[f32],
        coeffs: &[f32],
        z: &[f32],
        eta: f64,
        sigma: f64,
        pallas: bool,
    ) -> Result<Vec<f32>> {
        let (resp, rx) = channel();
        self.call(
            Job::Combine {
                y: y.to_vec(),
                deltas: deltas.to_vec(),
                coeffs: coeffs.to_vec(),
                z: z.to_vec(),
                eta,
                sigma,
                pallas,
                resp,
            },
            rx,
        )
    }

    /// Measure per-level cost in seconds/image (see engine).
    pub fn measure_costs(&self, reps: usize) -> Result<Vec<f64>> {
        let (resp, rx) = channel();
        self.call(Job::MeasureCosts { reps, resp }, rx)
    }

    /// Pre-compile all levels at a bucket size.
    pub fn warmup(&self, bucket: usize) -> Result<()> {
        let (resp, rx) = channel();
        self.call(Job::Warmup { bucket, resp }, rx)
    }

    /// (execute-call count, cumulative ns inside PJRT execute).
    pub fn exec_stats(&self) -> Result<(u64, u64)> {
        let (resp, rx) = channel();
        self.call(Job::ExecStats { resp }, rx)
    }

    /// Ask the executor thread to exit.
    pub fn stop(&self) {
        let _ = self.tx.send(Job::Stop);
    }
}
