//! The executor thread: sole owner of the PJRT client, serving eval jobs
//! over a channel.  [`ExecutorHandle`] is `Clone + Send + Sync`, so the
//! samplers (which require `Sync` drifts) and the multi-threaded
//! coordinator can all share one device owner.
//!
//! Zero-copy discipline (perf pass): request payloads travel in buffers
//! borrowed from the executor's **own** payload pool — the executor
//! returns them once the engine has consumed them — and every handle
//! owns **one** reusable response channel instead of allocating a fresh
//! channel per job.  Steady-state request traffic performs no channel or
//! payload allocations; [`ExecStats`] exposes the counters that prove it
//! (see `bench_runtime`).  The payload pool is deliberately separate
//! from [`crate::parallel::global_f32`]: samplers churn the global pool
//! with their own scratch, and sharing counters would dilute the
//! executor's zero-copy evidence beyond attribution.
//!
//! Cross-request micro-batching (CI pass): instead of handling one job
//! per loop turn, the executor drains its channel (plus an optional
//! linger window) and groups pending `Eps`/`EpsJvp` jobs by
//! `(level, bucket, t_bits, pallas)` — the same key under which their
//! device executions are interchangeable.  A multi-job group runs as
//! **one** padded-bucket execute ([`super::engine::Engine::eps_group`])
//! whose result slices are scattered back to each job's response
//! channel; a singleton group takes exactly the historical
//! one-job-at-a-time path, so latency and bit-exactness are unchanged
//! when there is no concurrency.  This is the MLMC amortisation move
//! applied across requests: many cheap evaluations sharing one kernel
//! should share one dispatch.  [`ExecOptions`] carries the knobs
//! (`exec_linger_us` / `exec_max_group` in the serve config); the
//! group counters land in [`ExecStats`] and the coordinator metrics.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::manifest::Manifest;
use crate::metrics::Metrics;
use crate::parallel::ScratchPool;
use crate::trace::{self, Attr, Stage, TraceTag};

/// Executor-owned payload pool: request payload buffers only, nothing
/// else, so its hit/miss counters measure exactly the request path.
static PAYLOAD_POOL: ScratchPool<f32> = ScratchPool::new();

fn payload_pool() -> &'static ScratchPool<f32> {
    &PAYLOAD_POOL
}

/// Donated output buffers (the return leg of the zero-copy discipline):
/// every result the engine hands back — eps fields, jvp pairs, grouped
/// scatter slices — is built in a buffer from this pool, and the
/// denoiser donates it back once the caller's slice is filled.  Kept
/// separate from [`PAYLOAD_POOL`] so each pool's hit/miss counters
/// attribute exactly one direction of the request path (the metrics
/// snapshot reports them side by side under `executor_pools`).
static OUTPUT_POOL: ScratchPool<f32> = ScratchPool::new();

pub(crate) fn output_pool() -> &'static ScratchPool<f32> {
    &OUTPUT_POOL
}

/// Process-wide (hits, misses) for the payload and output pools, in
/// that order — the metrics snapshot's `executor_pools` section.
pub fn scratch_pool_stats() -> (u64, u64, u64, u64) {
    let (ph, pm) = PAYLOAD_POOL.stats();
    let (oh, om) = OUTPUT_POOL.stats();
    (ph, pm, oh, om)
}

/// Aggregation knobs for the executor's event loop (the serve config's
/// `exec_linger_us` / `exec_max_group`; see `config.rs`).
#[derive(Clone, Copy, Debug)]
pub struct ExecOptions {
    /// How long (µs) the executor may hold an eps/jvp job to let more
    /// group members arrive.  The window only opens when at least one
    /// groupable peer is **already** queued and nothing else is — solo
    /// traffic never waits, and a queued non-peer job (another key, an
    /// admin call) is never stalled behind someone else's group, so
    /// lingering can only trade latency the waiting peers themselves
    /// opted into.  0 disables lingering entirely (drain-only grouping:
    /// only jobs that were concurrently in flight share a dispatch).
    pub linger_us: u64,
    /// Maximum jobs fused into one grouped execute; 1 disables grouping
    /// (every job takes the historical singleton path).
    pub max_group: usize,
    /// Liveness-poll period (µs) while a caller waits for a response:
    /// the bound on how late executor death is noticed, and therefore on
    /// stop/join latency (the serve config's `exec_poll_us`).
    pub poll_interval_us: u64,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { linger_us: 0, max_group: 16, poll_interval_us: 50_000 }
    }
}

/// Typed transport-death error: the executor thread (or its job
/// channel) is gone.  The supervisor replays exactly this class —
/// engine-level errors (bad shapes, synthetic faults, "engine
/// unavailable" refusals) pass through untouched, so a deterministic
/// failure can never turn into a retry loop.
#[derive(Debug)]
pub struct ExecutorGone(pub &'static str);

impl std::fmt::Display for ExecutorGone {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.0)
    }
}

impl std::error::Error for ExecutorGone {}

/// True iff `e`'s root cause is [`ExecutorGone`] (survives `context`
/// wrapping) — the class the supervisor may replay.
pub fn is_executor_gone(e: &anyhow::Error) -> bool {
    e.downcast_ref::<ExecutorGone>().is_some()
}

fn gone(why: &'static str) -> anyhow::Error {
    anyhow::Error::new(ExecutorGone(why))
}

/// Supervision knobs (the serve config's `retry_budget` /
/// `retry_backoff_us`).
#[derive(Clone, Copy, Debug)]
pub struct SupervisorOptions {
    /// Maximum respawn-and-replay attempts per request before the
    /// transport error is surfaced to the caller.
    pub retry_budget: usize,
    /// Base backoff (µs) before attempt k sleeps `base << k`, capped at
    /// 100 ms.
    pub retry_backoff_us: u64,
}

impl Default for SupervisorOptions {
    fn default() -> Self {
        SupervisorOptions { retry_budget: 5, retry_backoff_us: 500 }
    }
}

/// Executor-side counters: PJRT execute accounting plus the executor's
/// payload-pool hit/miss totals (the zero-copy evidence — a miss is a
/// fresh allocation, a hit is a reused buffer) and the micro-batching
/// evidence (groups formed, jobs that rode in them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Number of PJRT execute calls.
    pub exec_calls: u64,
    /// Cumulative nanoseconds inside PJRT execute.
    pub exec_ns: u64,
    /// Payload-pool takes served from the free-list.
    pub pool_hits: u64,
    /// Payload-pool takes that had to allocate (or grow).
    pub pool_misses: u64,
    /// Multi-job groups dispatched as one execute.
    pub exec_groups: u64,
    /// Jobs that rode in multi-job groups (mean occupancy =
    /// `grouped_jobs / exec_groups`).
    pub grouped_jobs: u64,
    /// Output-pool takes served from the free-list (donated result
    /// buffers reused on the return leg).
    pub out_pool_hits: u64,
    /// Output-pool takes that had to allocate (or grow).
    pub out_pool_misses: u64,
}

/// Unified response message (one channel per handle carries them all).
enum Resp {
    Vec(Result<Vec<f32>>),
    Pair(Result<(Vec<f32>, Vec<f32>)>),
    Costs(Result<Vec<f64>>),
    Unit(Result<()>),
    Stats(Result<ExecStats>),
}

enum Job {
    Eps { level: usize, x: Vec<f32>, t: f64, pallas: bool, trace: TraceTag, resp: Sender<Resp> },
    EpsJvp { level: usize, x: Vec<f32>, t: f64, v: Vec<f32>, trace: TraceTag, resp: Sender<Resp> },
    Combine {
        y: Vec<f32>,
        deltas: Vec<f32>,
        coeffs: Vec<f32>,
        z: Vec<f32>,
        eta: f64,
        sigma: f64,
        pallas: bool,
        resp: Sender<Resp>,
    },
    MeasureCosts { reps: usize, resp: Sender<Resp> },
    Warmup { bucket: usize, resp: Sender<Resp> },
    ExecStats { resp: Sender<Resp> },
    Stop,
}

/// Refuse a job (engine never came up, or it was still queued — alone or
/// in a pending aggregation group — when the executor stopped): recycle
/// its pooled payload buffers and answer with an error, so no caller is
/// ever left hanging on a response that cannot come.  Returns true on
/// `Stop`.
fn refuse(job: Job) -> bool {
    let pool = payload_pool();
    let unavailable = || anyhow!("engine unavailable");
    match job {
        Job::Eps { x, resp, .. } => {
            pool.put(x);
            let _ = resp.send(Resp::Vec(Err(unavailable())));
        }
        Job::EpsJvp { x, v, resp, .. } => {
            pool.put(x);
            pool.put(v);
            let _ = resp.send(Resp::Pair(Err(unavailable())));
        }
        Job::Combine { y, deltas, coeffs, z, resp, .. } => {
            pool.put(y);
            pool.put(deltas);
            pool.put(coeffs);
            pool.put(z);
            let _ = resp.send(Resp::Vec(Err(unavailable())));
        }
        Job::MeasureCosts { resp, .. } => {
            let _ = resp.send(Resp::Costs(Err(unavailable())));
        }
        Job::Warmup { resp, .. } => {
            let _ = resp.send(Resp::Unit(Err(unavailable())));
        }
        Job::ExecStats { resp } => {
            let _ = resp.send(Resp::Stats(Err(unavailable())));
        }
        Job::Stop => return true,
    }
    false
}

/// The key under which two jobs' device executions are interchangeable:
/// same artifact table entry (level + flavour), same singleton bucket,
/// bit-identical schedule time.  Jobs agreeing on all of it can share
/// one padded-bucket execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct GroupKey {
    jvp: bool,
    level: usize,
    bucket: usize,
    t_bits: u64,
    pallas: bool,
}

/// Per-level bucket tables snapshot (the part of the manifest the
/// grouping key needs), resolved once at executor start.
struct LevelBuckets {
    level: usize,
    eps: Vec<usize>,
    eps_pallas: Vec<usize>,
    jvp: Vec<usize>,
}

fn bucket_tables(manifest: &Manifest) -> Vec<LevelBuckets> {
    manifest
        .levels
        .iter()
        .map(|l| LevelBuckets {
            level: l.level,
            eps: l.eps.keys().copied().collect(),
            eps_pallas: l.eps_pallas.keys().copied().collect(),
            jvp: l.eps_jvp.keys().copied().collect(),
        })
        .collect()
}

/// The grouping key of a job, or `None` for jobs that never aggregate
/// (combine, admin, stop) and for levels without a bucket table.
fn key_of(job: &Job, dim: usize, tables: &[LevelBuckets]) -> Option<GroupKey> {
    let (jvp, level, x, t, pallas) = match job {
        Job::Eps { level, x, t, pallas, .. } => (false, *level, x, *t, *pallas),
        Job::EpsJvp { level, x, t, .. } => (true, *level, x, *t, false),
        _ => return None,
    };
    let lb = tables.iter().find(|l| l.level == level)?;
    let buckets = match (jvp, pallas) {
        (true, _) => &lb.jvp,
        (false, true) => &lb.eps_pallas,
        (false, false) => &lb.eps,
    };
    if buckets.is_empty() || dim == 0 {
        return None;
    }
    let bucket = Engine::pick_bucket(buckets, x.len() / dim);
    Some(GroupKey { jvp, level, bucket, t_bits: t.to_bits(), pallas })
}

/// Upper bound on jobs parked executor-side per drain turn (backstop
/// against a runaway producer; normal traffic never approaches it).
const DRAIN_CAP: usize = 4096;

/// The per-generation executor wiring: which thread's channel requests
/// go to, and that thread's liveness flag.  Shared (behind one
/// `RwLock`) by **all** clones of a handle, so a supervisor respawn —
/// a generation bump — is visible to every clone at its next call,
/// including clones parked inside `NeuralDenoiser` shard routing.
struct Wiring {
    tx: Sender<Job>,
    /// Cleared by [`AliveGuard`] when this generation's thread exits
    /// for any reason (Stop, channel close, panic).  Because the handle
    /// keeps a `Sender` for its reusable response channel, `recv` alone
    /// would never observe executor death — this flag is what turns an
    /// in-flight request into an error instead of a hang.
    alive: Arc<AtomicBool>,
    /// Jobs this generation's serve loop had drained but not yet handled
    /// at its last turn — the queue-depth gauge the fleet snapshot
    /// reports per member.
    depth: Arc<AtomicUsize>,
    /// Bumped on every supervisor respawn; callers record the value they
    /// observed so exactly one racer heals per dead generation.
    generation: u64,
}

/// Cloneable, thread-safe handle to the executor thread.  Each clone
/// owns its response channel; concurrent calls through one clone are
/// serialised (clone per thread for parallelism — concurrent clones'
/// jobs on the same (level, bucket, t) are exactly what the aggregation
/// loop fuses into one dispatch).
pub struct ExecutorHandle {
    wiring: Arc<RwLock<Wiring>>,
    manifest: Manifest,
    /// Liveness-poll period while waiting for a response.
    poll: Duration,
    /// Present on handles from [`spawn_supervised`]: transport-death
    /// errors are healed (respawn + replay) instead of surfaced.
    supervisor: Option<Arc<Supervisor>>,
    resp: Mutex<(Sender<Resp>, Receiver<Resp>)>,
}

impl Clone for ExecutorHandle {
    fn clone(&self) -> ExecutorHandle {
        ExecutorHandle {
            wiring: self.wiring.clone(),
            manifest: self.manifest.clone(),
            poll: self.poll,
            supervisor: self.supervisor.clone(),
            resp: Mutex::new(channel()),
        }
    }
}

/// Clears the executor-liveness flag on every exit path, including
/// panics unwinding out of engine calls.
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// Unified spawn surface for the executor: one builder replaces the
/// historical `spawn_executor` / `spawn_executor_with` /
/// `spawn_supervised` trio (kept as thin deprecated wrappers).  The
/// fleet (`runtime::fleet`) spawns every member through this builder,
/// which is why the three ad-hoc entry points had to collapse into one.
///
/// ```ignore
/// let ex = ExecutorBuilder::new(manifest)
///     .metrics(metrics)
///     .options(ExecOptions::default())
///     .supervised(SupervisorOptions::default())
///     .spawn()?;
/// ```
pub struct ExecutorBuilder {
    manifest: Manifest,
    metrics: Option<Metrics>,
    opts: ExecOptions,
    supervise: Option<SupervisorOptions>,
}

/// What [`ExecutorBuilder::spawn`] returns: the handle, plus generation
/// 0's join handle for *unsupervised* executors.  A supervised executor
/// reaps its own generations (the last thread exits when every handle
/// clone drops), so it exposes no join.
pub struct SpawnedExecutor {
    pub handle: ExecutorHandle,
    pub join: Option<JoinHandle<()>>,
}

impl ExecutorBuilder {
    /// Start from a manifest with default knobs: no metrics, default
    /// [`ExecOptions`], unsupervised (fail-fast on transport death).
    pub fn new(manifest: Manifest) -> ExecutorBuilder {
        ExecutorBuilder {
            manifest,
            metrics: None,
            opts: ExecOptions::default(),
            supervise: None,
        }
    }

    /// Record executor-side counters into this metrics registry.
    pub fn metrics(mut self, metrics: Metrics) -> ExecutorBuilder {
        self.metrics = Some(metrics);
        self
    }

    /// Aggregation/liveness knobs (the serve config's `exec_*` section).
    pub fn options(mut self, opts: ExecOptions) -> ExecutorBuilder {
        self.opts = opts;
        self
    }

    /// Run under the supervisor: transport death (thread panic, channel
    /// loss) is healed by respawn + bit-identical replay within the
    /// retry budget, instead of surfacing to the caller.
    pub fn supervised(mut self, retry: SupervisorOptions) -> ExecutorBuilder {
        self.supervise = Some(retry);
        self
    }

    /// Spawn generation 0 and wire up the handle (plus the supervision
    /// tree when [`ExecutorBuilder::supervised`] was called).
    pub fn spawn(self) -> Result<SpawnedExecutor> {
        let (tx, alive, depth, join) =
            spawn_exec_thread(self.manifest.clone(), self.metrics.clone(), self.opts, 0)?;
        let wiring = Arc::new(RwLock::new(Wiring { tx, alive, depth, generation: 0 }));
        let poll = Duration::from_micros(self.opts.poll_interval_us.max(1));
        match self.supervise {
            None => Ok(SpawnedExecutor {
                handle: ExecutorHandle {
                    wiring,
                    manifest: self.manifest,
                    poll,
                    supervisor: None,
                    resp: Mutex::new(channel()),
                },
                join: Some(join),
            }),
            Some(retry) => {
                let supervisor = Arc::new(Supervisor {
                    manifest: self.manifest.clone(),
                    metrics: self.metrics,
                    exec_opts: self.opts,
                    retry,
                    stopping: AtomicBool::new(false),
                    joins: Mutex::new(vec![join]),
                });
                Ok(SpawnedExecutor {
                    handle: ExecutorHandle {
                        wiring,
                        manifest: self.manifest,
                        poll,
                        supervisor: Some(supervisor),
                        resp: Mutex::new(channel()),
                    },
                    join: None,
                })
            }
        }
    }
}

/// Spawn the executor thread over `manifest`'s artifacts with default
/// aggregation knobs.  Returns the handle and the join handle (join
/// after dropping all handles/Stop).
#[deprecated(note = "use ExecutorBuilder::new(manifest).spawn()")]
pub fn spawn_executor(
    manifest: Manifest,
    metrics: Option<Metrics>,
) -> Result<(ExecutorHandle, JoinHandle<()>)> {
    let mut b = ExecutorBuilder::new(manifest);
    if let Some(m) = metrics {
        b = b.metrics(m);
    }
    let ex = b.spawn()?;
    Ok((ex.handle, ex.join.expect("unsupervised spawn returns a join handle")))
}

/// Spawn one executor thread generation: the raw (channel, liveness,
/// join) triple both the unsupervised spawn paths and the supervisor's
/// respawn share.
fn spawn_exec_thread(
    manifest: Manifest,
    metrics: Option<Metrics>,
    opts: ExecOptions,
    generation: u64,
) -> Result<(Sender<Job>, Arc<AtomicBool>, Arc<AtomicUsize>, JoinHandle<()>)> {
    let (tx, rx) = channel::<Job>();
    let alive = Arc::new(AtomicBool::new(true));
    let alive_flag = alive.clone();
    let depth = Arc::new(AtomicUsize::new(0));
    let depth_gauge = depth.clone();
    let join = std::thread::Builder::new()
        .name("pjrt-executor".to_string())
        .spawn(move || {
            let _alive = AliveGuard(alive_flag);
            let engine = match Engine::new(manifest) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[executor] failed to start engine: {e:#}");
                    // Drain jobs with errors so callers unblock.
                    for job in rx.iter() {
                        if refuse(job) {
                            break;
                        }
                    }
                    // Answer anything still queued behind the Stop.
                    while let Ok(job) = rx.try_recv() {
                        refuse(job);
                    }
                    return;
                }
            };
            serve_loop(engine, rx, metrics, opts, generation, depth_gauge);
        })?;
    Ok((tx, alive, depth, join))
}

/// [`spawn_executor`] with explicit aggregation knobs (the serve
/// config's `exec_linger_us` / `exec_max_group`).  Fail-fast: executor
/// death surfaces as a typed [`ExecutorGone`] error to callers — use
/// [`ExecutorBuilder::supervised`] for respawn + replay.
#[deprecated(note = "use ExecutorBuilder::new(manifest).options(opts).spawn()")]
pub fn spawn_executor_with(
    manifest: Manifest,
    metrics: Option<Metrics>,
    opts: ExecOptions,
) -> Result<(ExecutorHandle, JoinHandle<()>)> {
    let mut b = ExecutorBuilder::new(manifest).options(opts);
    if let Some(m) = metrics {
        b = b.metrics(m);
    }
    let ex = b.spawn()?;
    Ok((ex.handle, ex.join.expect("unsupervised spawn returns a join handle")))
}

/// The supervision tree's root: owns the manifest + knobs needed to
/// respawn a dead executor generation, and the join handles of every
/// generation spawned so far (dead ones are reaped at the next
/// respawn).  Shared by all clones of the supervised handle.
struct Supervisor {
    manifest: Manifest,
    metrics: Option<Metrics>,
    exec_opts: ExecOptions,
    retry: SupervisorOptions,
    /// Set by [`ExecutorHandle::stop`]: an intentional shutdown must
    /// never be "healed" back into existence.
    stopping: AtomicBool,
    joins: Mutex<Vec<JoinHandle<()>>>,
}

impl Supervisor {
    fn stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }

    /// Respawn the executor if generation `observed` is still the dead
    /// current one.  The join-handle mutex serialises healers: the first
    /// caller respawns; racers blocked behind it observe the bumped
    /// generation (or a live flag) and return without spawning a second
    /// thread.
    fn heal(&self, wiring: &Arc<RwLock<Wiring>>, observed: u64) -> Result<()> {
        if self.stopping() {
            return Err(gone("executor stopped"));
        }
        let mut joins = self.joins.lock().unwrap_or_else(|p| p.into_inner());
        let next_gen = {
            let w = wiring.read().unwrap_or_else(|p| p.into_inner());
            if w.generation > observed || w.alive.load(Ordering::SeqCst) {
                return Ok(()); // a racing caller already healed this death
            }
            w.generation + 1
        };
        // Reap the dead generation (its thread has exited or is
        // unwinding; join returns promptly) before spawning the next.
        for j in joins.drain(..) {
            let _ = j.join();
        }
        let (tx, alive, depth, join) = spawn_exec_thread(
            self.manifest.clone(),
            self.metrics.clone(),
            self.exec_opts,
            next_gen,
        )?;
        joins.push(join);
        let mut w = wiring.write().unwrap_or_else(|p| p.into_inner());
        w.tx = tx;
        w.alive = alive;
        w.depth = depth;
        w.generation = next_gen;
        if let Some(m) = &self.metrics {
            m.restarts.inc();
        }
        // Chaos tag: the respawn lands in the affected request's trace,
        // so a retried request's timeline shows both generations.
        let tag = trace::current();
        if tag.sampled() {
            let rec = trace::recorder();
            let now = rec.now_us();
            rec.record_span(
                rec.span_id(),
                tag,
                Stage::Restart,
                now,
                now,
                Attr { generation: next_gen + 1, ..Attr::default() },
            );
        }
        eprintln!("[supervisor] executor respawned (generation {})", w.generation);
        Ok(())
    }
}

/// Spawn a **supervised** executor: like [`spawn_executor_with`], but
/// transport death (thread panic, channel loss) is detected at the next
/// call, the executor is respawned from the manifest, and the failed
/// request is replayed — with capped exponential backoff, up to
/// `retry.retry_budget` attempts.  Replays are bit-identical to
/// first-try results: each attempt rebuilds its payload from the
/// caller's slice and the engine's math is a pure function of the
/// inputs.  No join handle is returned; generations are reaped at
/// respawn and the last thread exits when every handle clone drops.
#[deprecated(note = "use ExecutorBuilder::new(manifest).options(opts).supervised(retry).spawn()")]
pub fn spawn_supervised(
    manifest: Manifest,
    metrics: Option<Metrics>,
    opts: ExecOptions,
    retry: SupervisorOptions,
) -> Result<ExecutorHandle> {
    let mut b = ExecutorBuilder::new(manifest).options(opts).supervised(retry);
    if let Some(m) = metrics {
        b = b.metrics(m);
    }
    Ok(b.spawn()?.handle)
}

/// The executor's event loop: aggregation over the job channel.
/// `generation` stamps this thread's Execute spans so a supervisor
/// respawn is visible in a traced request's timeline.
fn serve_loop(
    mut engine: Engine,
    rx: Receiver<Job>,
    metrics: Option<Metrics>,
    opts: ExecOptions,
    generation: u64,
    depth: Arc<AtomicUsize>,
) {
    let dim = engine.manifest().dim;
    let tables = bucket_tables(engine.manifest());
    let max_group = opts.max_group.max(1);
    // Jobs drained off the channel but not yet handled, in arrival order.
    let mut pending: VecDeque<Job> = VecDeque::new();
    // Lifetime group counters (surfaced through ExecStats).
    let mut exec_groups = 0u64;
    let mut grouped_jobs = 0u64;
    'serve: loop {
        let job = match pending.pop_front() {
            Some(j) => j,
            None => match rx.recv() {
                Ok(j) => j,
                Err(_) => break 'serve, // all handles dropped
            },
        };
        if matches!(job, Job::Stop) {
            break 'serve;
        }

        // The head job's key is computed even when grouping is off: the
        // trace spans borrow its bucket for their cost attribution.
        let head_key = key_of(&job, dim, &tables);
        let mut group: Vec<Job> = vec![job];
        if let (true, Some(key)) = (max_group > 1, head_key) {
            // Opportunistic drain: everything already queued is a
            // grouping candidate at zero latency cost.
            while pending.len() < DRAIN_CAP {
                match rx.try_recv() {
                    Ok(j) => pending.push_back(j),
                    Err(_) => break,
                }
            }
            // One O(pending) census (each job's key computed once):
            // same-key peers vs everything else.  A Stop counts as
            // "other" and ends the scan — nothing behind it matters for
            // this turn.
            let mut peers = 0usize;
            let mut others = 0usize;
            for j in &pending {
                if matches!(*j, Job::Stop) {
                    others += 1;
                    break;
                }
                if key_of(j, dim, &tables) == Some(key) {
                    peers += 1;
                } else {
                    others += 1;
                }
            }
            // Linger: hold the group open for up to `linger_us` — but
            // only while at least one groupable peer is already waiting
            // (solo callers never wait) and nothing *else* is queued (a
            // non-peer job must not stall behind someone else's group).
            // Counts update incrementally per arrival: no rescans on the
            // device-owner thread.
            if opts.linger_us > 0 && peers >= 1 && others == 0 {
                let deadline = Instant::now() + Duration::from_micros(opts.linger_us);
                while 1 + peers < max_group && others == 0 {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(j) => {
                            if matches!(j, Job::Stop) {
                                others += 1;
                            } else if key_of(&j, dim, &tables) == Some(key) {
                                peers += 1;
                            } else {
                                others += 1;
                            }
                            pending.push_back(j);
                        }
                        Err(_) => break, // timeout or disconnect
                    }
                }
            }
            // Extract up to max_group-1 same-key peers, preserving the
            // arrival order of everything else.  The scan stops at the
            // first Stop: jobs sent after a shutdown request are never
            // pulled forward past it.
            if peers > 0 {
                let mut kept: VecDeque<Job> = VecDeque::with_capacity(pending.len());
                let mut sealed = false;
                for j in pending.drain(..) {
                    if matches!(j, Job::Stop) {
                        sealed = true;
                        kept.push_back(j);
                    } else if !sealed
                        && group.len() < max_group
                        && key_of(&j, dim, &tables) == Some(key)
                    {
                        group.push(j);
                    } else {
                        kept.push_back(j);
                    }
                }
                pending = kept;
            }
        }

        // Queue-depth gauge for the fleet snapshot: what this turn left
        // parked after grouping (a relaxed store; readers want a trend,
        // not a fence).
        depth.store(pending.len(), Ordering::Relaxed);

        if group.len() > 1 {
            let n = group.len() as u64;
            exec_groups += 1;
            grouped_jobs += n;
            if let Some(m) = &metrics {
                // Mean occupancy is derived at snapshot time from these
                // two counters; the historical per-group gauge write
                // misreported under concurrent executor generations.
                m.exec_groups.inc();
                m.grouped_jobs.add(n);
            }
            run_group(&mut engine, group, &metrics, head_key, generation);
        } else {
            run_single(
                &mut engine,
                group.pop().expect("singleton group"),
                &metrics,
                (exec_groups, grouped_jobs),
                head_key,
                generation,
            );
        }
    }
    // Stop (or handle drop) raced with queued work — possibly including
    // members of a not-yet-dispatched aggregation group parked in
    // `pending`: answer every one of them rather than leaving callers
    // waiting on a response that will never come.
    for job in pending {
        refuse(job);
    }
    while let Ok(job) = rx.try_recv() {
        refuse(job);
    }
    depth.store(0, Ordering::Relaxed);
}

/// The shared (kind, level, t, pallas) of a formed group, copied out of
/// its first member before the jobs are consumed.
enum GroupKind {
    Eps { level: usize, t: f64, pallas: bool },
    Jvp { level: usize, t: f64 },
}

/// Dispatch one multi-job group as a single padded-bucket execute and
/// scatter the result slices back per job.  If the engine errors
/// mid-group, **every** member receives the error — a dead engine must
/// never turn into a hang for the jobs that happened to share its last
/// dispatch.
fn run_group(
    engine: &mut Engine,
    group: Vec<Job>,
    metrics: &Option<Metrics>,
    key: Option<GroupKey>,
    generation: u64,
) {
    let pool = payload_pool();
    // All jobs in a group share kind/level/t/pallas by construction.
    let kind = match group.first() {
        Some(Job::Eps { level, t, pallas, .. }) => {
            GroupKind::Eps { level: *level, t: *t, pallas: *pallas }
        }
        Some(Job::EpsJvp { level, t, .. }) => GroupKind::Jvp { level: *level, t: *t },
        _ => unreachable!("only eps/jvp jobs are grouped"),
    };
    let bucket = key.map_or(0, |k| k.bucket);
    match kind {
        GroupKind::Eps { level, t, pallas } => {
            let mut xs = Vec::with_capacity(group.len());
            let mut resps = Vec::with_capacity(group.len());
            let mut tags = Vec::with_capacity(group.len());
            for job in group {
                if let Job::Eps { x, trace, resp, .. } = job {
                    xs.push(x);
                    tags.push(trace);
                    resps.push(resp);
                }
            }
            let parts: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
            let traced = tags.iter().any(TraceTag::sampled);
            let rec = trace::recorder();
            let start_us = if traced { rec.now_us() } else { 0 };
            let t0 = Instant::now();
            let r = engine.eps_group(level, &parts, t, pallas);
            let dt = t0.elapsed();
            let exec_end_us = if traced { rec.now_us() } else { 0 };
            if let Some(m) = metrics {
                m.execute_latency.record(dt);
                m.record_level_execute(level, dt);
            }
            match r {
                Ok(outs) => {
                    for (out, resp) in outs.into_iter().zip(&resps) {
                        let _ = resp.send(Resp::Vec(Ok(out)));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for resp in &resps {
                        let _ = resp.send(Resp::Vec(Err(anyhow!("grouped eps failed: {msg}"))));
                    }
                }
            }
            if traced {
                let end_us = rec.now_us();
                let attr = Attr {
                    level: level as u32,
                    bucket: bucket as u32,
                    t_bits: t.to_bits(),
                    generation: generation + 1,
                };
                let gen_only = Attr { generation: generation + 1, ..Attr::default() };
                for tag in tags.iter().filter(|tag| tag.sampled()) {
                    let g = rec.span_id();
                    rec.record_span(g, *tag, Stage::ExecGroup, start_us, end_us, gen_only);
                    rec.record_span(
                        rec.span_id(),
                        tag.under(g),
                        Stage::Execute,
                        start_us,
                        exec_end_us,
                        attr,
                    );
                    rec.record_span(
                        rec.span_id(),
                        tag.under(g),
                        Stage::Scatter,
                        exec_end_us,
                        end_us,
                        gen_only,
                    );
                }
            }
            for x in xs {
                pool.put(x);
            }
        }
        GroupKind::Jvp { level, t } => {
            let mut xvs = Vec::with_capacity(group.len());
            let mut resps = Vec::with_capacity(group.len());
            let mut tags = Vec::with_capacity(group.len());
            for job in group {
                if let Job::EpsJvp { x, v, trace, resp, .. } = job {
                    xvs.push((x, v));
                    tags.push(trace);
                    resps.push(resp);
                }
            }
            let parts: Vec<(&[f32], &[f32])> =
                xvs.iter().map(|(x, v)| (x.as_slice(), v.as_slice())).collect();
            let traced = tags.iter().any(TraceTag::sampled);
            let rec = trace::recorder();
            let start_us = if traced { rec.now_us() } else { 0 };
            let r = engine.eps_jvp_group(level, &parts, t);
            match r {
                Ok(outs) => {
                    for (out, resp) in outs.into_iter().zip(&resps) {
                        let _ = resp.send(Resp::Pair(Ok(out)));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for resp in &resps {
                        let _ = resp.send(Resp::Pair(Err(anyhow!("grouped jvp failed: {msg}"))));
                    }
                }
            }
            if traced {
                let end_us = rec.now_us();
                let attr = Attr {
                    level: level as u32,
                    bucket: bucket as u32,
                    t_bits: t.to_bits(),
                    generation: generation + 1,
                };
                for tag in tags.iter().filter(|tag| tag.sampled()) {
                    rec.record_span(rec.span_id(), *tag, Stage::Execute, start_us, end_us, attr);
                }
            }
            for (x, v) in xvs {
                pool.put(x);
                pool.put(v);
            }
        }
    }
}

/// Handle one job exactly as the historical one-at-a-time loop did.
fn run_single(
    engine: &mut Engine,
    job: Job,
    metrics: &Option<Metrics>,
    group_counters: (u64, u64),
    key: Option<GroupKey>,
    generation: u64,
) {
    let pool = payload_pool();
    let bucket = key.map_or(0, |k| k.bucket);
    match job {
        Job::Eps { level, x, t, pallas, trace, resp } => {
            let rec = trace::recorder();
            let start_us = if trace.sampled() { rec.now_us() } else { 0 };
            let t0 = Instant::now();
            let r = engine.eps(level, &x, t, pallas);
            let dt = t0.elapsed();
            if let Some(m) = metrics {
                m.execute_latency.record(dt);
                m.record_level_execute(level, dt);
            }
            if trace.sampled() {
                rec.record(
                    trace,
                    Stage::Execute,
                    start_us,
                    Attr {
                        level: level as u32,
                        bucket: bucket as u32,
                        t_bits: t.to_bits(),
                        generation: generation + 1,
                    },
                );
            }
            pool.put(x);
            let _ = resp.send(Resp::Vec(r));
        }
        Job::EpsJvp { level, x, t, v, trace, resp } => {
            let rec = trace::recorder();
            let start_us = if trace.sampled() { rec.now_us() } else { 0 };
            let r = engine.eps_jvp(level, &x, t, &v);
            if trace.sampled() {
                rec.record(
                    trace,
                    Stage::Execute,
                    start_us,
                    Attr {
                        level: level as u32,
                        bucket: bucket as u32,
                        t_bits: t.to_bits(),
                        generation: generation + 1,
                    },
                );
            }
            pool.put(x);
            pool.put(v);
            let _ = resp.send(Resp::Pair(r));
        }
        Job::Combine { y, deltas, coeffs, z, eta, sigma, pallas, resp } => {
            let r = engine.combine(&y, &deltas, &coeffs, &z, eta, sigma, pallas);
            pool.put(y);
            pool.put(deltas);
            pool.put(coeffs);
            pool.put(z);
            let _ = resp.send(Resp::Vec(r));
        }
        Job::MeasureCosts { reps, resp } => {
            let _ = resp.send(Resp::Costs(engine.measure_costs(reps)));
        }
        Job::Warmup { bucket, resp } => {
            let _ = resp.send(Resp::Unit(engine.warmup(bucket)));
        }
        Job::ExecStats { resp } => {
            let (pool_hits, pool_misses) = pool.stats();
            let (out_pool_hits, out_pool_misses) = output_pool().stats();
            let _ = resp.send(Resp::Stats(Ok(ExecStats {
                exec_calls: engine.exec_calls,
                exec_ns: engine.exec_ns,
                pool_hits,
                pool_misses,
                exec_groups: group_counters.0,
                grouped_jobs: group_counters.1,
                out_pool_hits,
                out_pool_misses,
            })));
        }
        Job::Stop => unreachable!("Stop is handled by the serve loop"),
    }
}

/// Copy a payload into a buffer from the executor's payload pool
/// (reused, not allocated, after warmup) for the trip to the executor
/// thread.  Multi-megabyte batch payloads are memcpy'd in parallel on
/// the worker pool ([`crate::parallel::par_copy`] shards above
/// `COPY_GRAIN`); everything smaller stays a plain wait-free
/// `copy_from_slice`.
fn pooled_copy(src: &[f32]) -> Vec<f32> {
    let mut buf = payload_pool().take_vec(src.len());
    crate::parallel::par_copy(src, &mut buf);
    buf
}

impl ExecutorHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The current executor generation (0 until the first supervisor
    /// respawn).  Shared by every clone of this handle.
    pub fn generation(&self) -> u64 {
        self.wiring.read().unwrap_or_else(|p| p.into_inner()).generation
    }

    /// Whether this handle runs under the supervisor (respawn + replay
    /// on transport death).
    pub fn is_supervised(&self) -> bool {
        self.supervisor.is_some()
    }

    /// Jobs the executor's serve loop had drained but not yet handled at
    /// its last turn — a sampled gauge, not a fenced count.  The fleet
    /// snapshot reports it per member as `queue_depth`.
    pub fn queue_depth(&self) -> usize {
        self.wiring.read().unwrap_or_else(|p| p.into_inner()).depth.load(Ordering::Relaxed)
    }

    /// Send one job and wait for its answer on this handle's reusable
    /// response channel.  Waiting polls the liveness flag every
    /// `poll_interval_us`: if the executor thread exits (Stop race,
    /// engine panic) with this request in flight, the call errors
    /// instead of hanging — the handle's own `Sender` keeps the response
    /// channel connected, so disconnect can never signal death here.
    ///
    /// Transport death always surfaces as a typed [`ExecutorGone`]; a
    /// failed attempt provably left **no** response behind (the dead
    /// thread's sends all happen before its liveness flag clears, and
    /// the flag check re-drains the channel), so a supervisor replay can
    /// never pair a request with a stale answer.
    fn call(&self, make: impl FnOnce(Sender<Resp>) -> Job) -> Result<Resp> {
        let (tx, alive) = {
            let w = self.wiring.read().unwrap_or_else(|p| p.into_inner());
            (w.tx.clone(), w.alive.clone())
        };
        let slot = self.resp.lock().map_err(|_| anyhow!("executor handle poisoned"))?;
        tx.send(make(slot.0.clone())).map_err(|_| gone("executor thread gone"))?;
        loop {
            match slot.1.recv_timeout(self.poll) {
                Ok(r) => return Ok(r),
                Err(RecvTimeoutError::Timeout) => {
                    if !alive.load(Ordering::SeqCst) {
                        // One last look: the answer may have been sent
                        // just before the thread exited.
                        if let Ok(r) = slot.1.try_recv() {
                            return Ok(r);
                        }
                        return Err(gone("executor thread exited with the request in flight"));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(gone("executor dropped response"));
                }
            }
        }
    }

    /// Run one request attempt, healing transport death when this handle
    /// is supervised: on [`ExecutorGone`] the supervisor respawns the
    /// executor (exactly once per dead generation, however many clones
    /// race) and `f` is re-invoked — rebuilding the job, payload copies
    /// included, from the caller's original arguments, which is what
    /// makes a replay bit-identical to a first try.  Engine-level errors
    /// return immediately; attempts stop at the retry budget.
    fn retrying<T>(&self, f: impl Fn(&ExecutorHandle) -> Result<T>) -> Result<T> {
        let Some(sup) = &self.supervisor else {
            return f(self);
        };
        let mut attempt = 0u32;
        loop {
            let observed = self.wiring.read().unwrap_or_else(|p| p.into_inner()).generation;
            match f(self) {
                Ok(v) => return Ok(v),
                Err(e) if is_executor_gone(&e) && !sup.stopping() => {
                    if attempt as usize >= sup.retry.retry_budget {
                        return Err(e.context(format!(
                            "retry budget ({}) exhausted",
                            sup.retry.retry_budget
                        )));
                    }
                    if let Some(m) = &sup.metrics {
                        m.retries.inc();
                    }
                    // Chaos tag: mark the replay in the affected trace
                    // (attr decodes to the generation that died).
                    let tag = trace::current();
                    if tag.sampled() {
                        let rec = trace::recorder();
                        let now = rec.now_us();
                        rec.record_span(
                            rec.span_id(),
                            tag,
                            Stage::Replay,
                            now,
                            now,
                            Attr { generation: observed + 1, ..Attr::default() },
                        );
                    }
                    let backoff_us = (sup.retry.retry_backoff_us << attempt.min(20)).min(100_000);
                    if backoff_us > 0 {
                        std::thread::sleep(Duration::from_micros(backoff_us));
                    }
                    sup.heal(&self.wiring, observed)?;
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn call_vec(&self, make: impl FnOnce(Sender<Resp>) -> Job) -> Result<Vec<f32>> {
        match self.call(make)? {
            Resp::Vec(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        }
    }

    /// Evaluate a level's eps network on a flattened `[n, dim]` batch.
    /// The calling thread's active trace tag (set by the lane / shard
    /// plumbing) rides along so sampled requests trace end to end.
    pub fn eps(&self, level: usize, x: &[f32], t: f64) -> Result<Vec<f32>> {
        self.retrying(|h| {
            let x = pooled_copy(x);
            let trace = trace::current();
            h.call_vec(|resp| Job::Eps { level, x, t, pallas: false, trace, resp })
        })
    }

    /// Same through the Pallas-flavour parity artifact.
    pub fn eps_pallas(&self, level: usize, x: &[f32], t: f64) -> Result<Vec<f32>> {
        self.retrying(|h| {
            let x = pooled_copy(x);
            let trace = trace::current();
            h.call_vec(|resp| Job::Eps { level, x, t, pallas: true, trace, resp })
        })
    }

    /// Evaluate (eps, ∂eps·v).
    pub fn eps_jvp(&self, level: usize, x: &[f32], t: f64, v: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.retrying(|h| {
            let x = pooled_copy(x);
            let v = pooled_copy(v);
            let trace = trace::current();
            match h.call(|resp| Job::EpsJvp { level, x, t, v, trace, resp })? {
                Resp::Pair(r) => r,
                _ => Err(anyhow!("executor protocol mismatch")),
            }
        })
    }

    /// Fused ML-EM combine step (see `engine::Engine::combine`).
    #[allow(clippy::too_many_arguments)]
    pub fn combine(
        &self,
        y: &[f32],
        deltas: &[f32],
        coeffs: &[f32],
        z: &[f32],
        eta: f64,
        sigma: f64,
        pallas: bool,
    ) -> Result<Vec<f32>> {
        self.retrying(|h| {
            let y = pooled_copy(y);
            let deltas = pooled_copy(deltas);
            let coeffs = pooled_copy(coeffs);
            let z = pooled_copy(z);
            h.call_vec(|resp| Job::Combine { y, deltas, coeffs, z, eta, sigma, pallas, resp })
        })
    }

    /// Measure per-level cost in seconds/image (see engine).
    pub fn measure_costs(&self, reps: usize) -> Result<Vec<f64>> {
        self.retrying(|h| match h.call(|resp| Job::MeasureCosts { reps, resp })? {
            Resp::Costs(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        })
    }

    /// Pre-compile all levels at a bucket size.
    pub fn warmup(&self, bucket: usize) -> Result<()> {
        self.retrying(|h| match h.call(|resp| Job::Warmup { bucket, resp })? {
            Resp::Unit(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        })
    }

    /// Execute-call, buffer-reuse, and grouping counters (see
    /// [`ExecStats`]).
    pub fn exec_stats(&self) -> Result<ExecStats> {
        self.retrying(|h| match h.call(|resp| Job::ExecStats { resp })? {
            Resp::Stats(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        })
    }

    /// Ask the executor thread to exit.  On a supervised handle this
    /// also latches the stopping flag first, so no concurrent caller
    /// respawns the executor after (or while) it shuts down.
    pub fn stop(&self) {
        if let Some(sup) = &self.supervisor {
            sup.stopping.store(true, Ordering::SeqCst);
        }
        let w = self.wiring.read().unwrap_or_else(|p| p.into_inner());
        let _ = w.tx.send(Job::Stop);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The payload pool is executor-local: its counters move only when
    /// request payloads do, and a put/copy cycle is a pool hit (the
    /// attribution `bench_runtime` relies on).  No other test in this
    /// binary touches `PAYLOAD_POOL`, so the deltas are deterministic.
    /// (Executor traffic tests live in `tests/exec_batching.rs` — a
    /// separate process — for the same reason.)
    #[test]
    fn payload_pool_is_executor_local_and_reuses() {
        let (h0, m0) = payload_pool().stats();
        let a = pooled_copy(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, vec![1.0, 2.0, 3.0, 4.0]);
        payload_pool().put(a);
        let b = pooled_copy(&[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(b, vec![5.0, 6.0, 7.0, 8.0]);
        payload_pool().put(b);
        let (h1, m1) = payload_pool().stats();
        assert_eq!(m1 - m0, 1, "first copy allocates");
        assert_eq!(h1 - h0, 1, "second copy reuses the parked buffer");
    }

    /// The output pool recycles donated buffers, and
    /// [`scratch_pool_stats`] reports (payload, output) in that slot
    /// order.  Deltas are `>=`-checked: unlike the payload pool, other
    /// tests in this binary may legally drive the output pool.
    #[test]
    fn output_pool_recycles_and_stats_slots_are_payload_then_output() {
        let before = scratch_pool_stats();
        let v = output_pool().take_vec(47);
        output_pool().put(v);
        let w = output_pool().take_vec(47); // a 47-wide buffer is parked: hit
        assert_eq!(w.len(), 47);
        output_pool().put(w);
        let after = scratch_pool_stats();
        assert!(
            after.2 + after.3 >= before.2 + before.3 + 2,
            "output-pool takes must land in the 3rd/4th stat slots"
        );
        assert!(after.2 > before.2, "the re-take of a parked width is a hit");
        assert!(after.0 >= before.0 && after.1 >= before.1, "payload slots never regress");
    }

    #[test]
    fn exec_options_defaults_group_without_lingering() {
        let o = ExecOptions::default();
        assert_eq!(o.linger_us, 0, "no added latency by default");
        assert!(o.max_group > 1, "drain-only grouping on by default");
        assert_eq!(o.poll_interval_us, 50_000, "historical 50 ms liveness poll by default");
    }

    #[test]
    fn executor_gone_survives_context_wrapping() {
        let e = gone("executor thread gone");
        assert!(is_executor_gone(&e));
        let wrapped = e.context("retry budget (5) exhausted");
        assert!(is_executor_gone(&wrapped), "downcast must see through context layers");
        assert!(!is_executor_gone(&anyhow!("engine unavailable")));
        assert!(!is_executor_gone(&anyhow!("grouped eps failed: bad shapes")));
    }

    /// A minimal self-consistent manifest for spawn-shape tests: the
    /// engine may refuse to come up over it, but spawn itself succeeds
    /// and the thread drains jobs — all the builder tests need.
    fn tiny_manifest() -> Manifest {
        use super::super::manifest::{CombineMeta, LevelMeta};
        Manifest {
            dir: std::path::PathBuf::from("/nonexistent"),
            img: 2,
            channels: 1,
            dim: 4,
            batch_buckets: vec![1],
            jvp_buckets: Vec::new(),
            schedule_s: crate::sde::schedule::COSINE_S,
            t_max: crate::sde::schedule::T_MAX,
            combine: CombineMeta {
                batch: 1,
                levels: 1,
                ref_file: String::new(),
                pallas_file: String::new(),
            },
            holdout_file: String::new(),
            holdout_count: 0,
            levels: vec![LevelMeta {
                level: 1,
                params: 0,
                flops_per_image: 1,
                holdout_loss: 0.1,
                eps: Default::default(),
                eps_jvp: Default::default(),
                eps_pallas: Default::default(),
            }],
        }
    }

    /// The builder is the single spawn surface: supervision is opt-in,
    /// an unsupervised spawn exposes generation 0's join handle, and a
    /// supervised one reaps its own generations (no join exposed).
    /// Spawning needs no artifacts — a manifest whose engine cannot come
    /// up still yields a live thread that refuses jobs, which is all
    /// this shape test needs.
    #[test]
    fn builder_spawn_shapes_supervision() {
        let manifest = tiny_manifest();
        let plain = ExecutorBuilder::new(manifest.clone()).spawn().unwrap();
        assert!(plain.join.is_some(), "unsupervised spawn returns the join handle");
        assert!(!plain.handle.is_supervised());
        assert_eq!(plain.handle.generation(), 0);
        plain.handle.stop();
        let _ = plain.join.unwrap().join();
        let sup = ExecutorBuilder::new(manifest)
            .options(ExecOptions::default())
            .supervised(SupervisorOptions::default())
            .spawn()
            .unwrap();
        assert!(sup.join.is_none(), "the supervisor reaps its own generations");
        assert!(sup.handle.is_supervised());
        sup.handle.stop();
    }

    /// The deprecated trio still compiles and still delegates to the
    /// builder (same handle shapes as before the collapse).
    #[test]
    #[allow(deprecated)]
    fn legacy_spawn_wrappers_delegate_to_builder() {
        let manifest = tiny_manifest();
        let (h, join) = spawn_executor(manifest.clone(), None).unwrap();
        assert!(!h.is_supervised());
        h.stop();
        let _ = join.join();
        let sup =
            spawn_supervised(manifest, None, ExecOptions::default(), SupervisorOptions::default())
                .unwrap();
        assert!(sup.is_supervised());
        sup.stop();
    }

    #[test]
    fn supervisor_options_default_to_bounded_retries() {
        let s = SupervisorOptions::default();
        assert!(s.retry_budget >= 1, "at least one replay attempt");
        assert!(s.retry_budget <= 100, "budget is a bound, not a loop");
        // Worst-case backoff stays capped regardless of the attempt
        // index (the shift saturates into the 100 ms ceiling).
        let worst = (s.retry_backoff_us << 20u32).min(100_000);
        assert!(worst <= 100_000);
    }
}
