//! The executor thread: sole owner of the PJRT client, serving eval jobs
//! over a channel.  [`ExecutorHandle`] is `Clone + Send + Sync`, so the
//! samplers (which require `Sync` drifts) and the multi-threaded
//! coordinator can all share one device owner.
//!
//! Zero-copy discipline (perf pass): request payloads travel in buffers
//! borrowed from the executor's **own** payload pool — the executor
//! returns them once the engine has consumed them — and every handle
//! owns **one** reusable response channel instead of allocating a fresh
//! channel per job.  Steady-state request traffic performs no channel or
//! payload allocations; [`ExecStats`] exposes the counters that prove it
//! (see `bench_runtime`).  The payload pool is deliberately separate
//! from [`crate::parallel::global_f32`]: samplers churn the global pool
//! with their own scratch, and sharing counters would dilute the
//! executor's zero-copy evidence beyond attribution.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::engine::Engine;
use super::manifest::Manifest;
use crate::metrics::Metrics;
use crate::parallel::ScratchPool;

/// Executor-owned payload pool: request payload buffers only, nothing
/// else, so its hit/miss counters measure exactly the request path.
static PAYLOAD_POOL: ScratchPool<f32> = ScratchPool::new();

fn payload_pool() -> &'static ScratchPool<f32> {
    &PAYLOAD_POOL
}

/// Executor-side counters: PJRT execute accounting plus the executor's
/// payload-pool hit/miss totals (the zero-copy evidence — a miss is a
/// fresh allocation, a hit is a reused buffer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Number of PJRT execute calls.
    pub exec_calls: u64,
    /// Cumulative nanoseconds inside PJRT execute.
    pub exec_ns: u64,
    /// Payload-pool takes served from the free-list.
    pub pool_hits: u64,
    /// Payload-pool takes that had to allocate (or grow).
    pub pool_misses: u64,
}

/// Unified response message (one channel per handle carries them all).
enum Resp {
    Vec(Result<Vec<f32>>),
    Pair(Result<(Vec<f32>, Vec<f32>)>),
    Costs(Result<Vec<f64>>),
    Unit(Result<()>),
    Stats(Result<ExecStats>),
}

enum Job {
    Eps { level: usize, x: Vec<f32>, t: f64, pallas: bool, resp: Sender<Resp> },
    EpsJvp { level: usize, x: Vec<f32>, t: f64, v: Vec<f32>, resp: Sender<Resp> },
    Combine {
        y: Vec<f32>,
        deltas: Vec<f32>,
        coeffs: Vec<f32>,
        z: Vec<f32>,
        eta: f64,
        sigma: f64,
        pallas: bool,
        resp: Sender<Resp>,
    },
    MeasureCosts { reps: usize, resp: Sender<Resp> },
    Warmup { bucket: usize, resp: Sender<Resp> },
    ExecStats { resp: Sender<Resp> },
    Stop,
}

/// Refuse a job because the engine never came up: recycle its pooled
/// payload buffers and answer with an error.  Returns true on `Stop`.
fn refuse(job: Job) -> bool {
    let pool = payload_pool();
    let unavailable = || anyhow!("engine unavailable");
    match job {
        Job::Eps { x, resp, .. } => {
            pool.put(x);
            let _ = resp.send(Resp::Vec(Err(unavailable())));
        }
        Job::EpsJvp { x, v, resp, .. } => {
            pool.put(x);
            pool.put(v);
            let _ = resp.send(Resp::Pair(Err(unavailable())));
        }
        Job::Combine { y, deltas, coeffs, z, resp, .. } => {
            pool.put(y);
            pool.put(deltas);
            pool.put(coeffs);
            pool.put(z);
            let _ = resp.send(Resp::Vec(Err(unavailable())));
        }
        Job::MeasureCosts { resp, .. } => {
            let _ = resp.send(Resp::Costs(Err(unavailable())));
        }
        Job::Warmup { resp, .. } => {
            let _ = resp.send(Resp::Unit(Err(unavailable())));
        }
        Job::ExecStats { resp } => {
            let _ = resp.send(Resp::Stats(Err(unavailable())));
        }
        Job::Stop => return true,
    }
    false
}

/// Cloneable, thread-safe handle to the executor thread.  Each clone
/// owns its response channel; concurrent calls through one clone are
/// serialised (clone per thread for parallelism — the executor thread
/// serialises device work anyway).
pub struct ExecutorHandle {
    tx: Sender<Job>,
    manifest: Manifest,
    /// Cleared by [`AliveGuard`] when the executor thread exits for any
    /// reason (Stop, channel close, panic).  Because the handle keeps a
    /// `Sender` for its reusable response channel, `recv` alone would
    /// never observe executor death — this flag is what turns an
    /// in-flight request into an error instead of a hang.
    alive: Arc<AtomicBool>,
    resp: Mutex<(Sender<Resp>, Receiver<Resp>)>,
}

impl Clone for ExecutorHandle {
    fn clone(&self) -> ExecutorHandle {
        ExecutorHandle {
            tx: self.tx.clone(),
            manifest: self.manifest.clone(),
            alive: self.alive.clone(),
            resp: Mutex::new(channel()),
        }
    }
}

/// Clears the executor-liveness flag on every exit path, including
/// panics unwinding out of engine calls.
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// Spawn the executor thread over `manifest`'s artifacts.  Returns the
/// handle and the join handle (join after dropping all handles/Stop).
pub fn spawn_executor(
    manifest: Manifest,
    metrics: Option<Metrics>,
) -> Result<(ExecutorHandle, JoinHandle<()>)> {
    let (tx, rx) = channel::<Job>();
    let handle_manifest = manifest.clone();
    let alive = Arc::new(AtomicBool::new(true));
    let alive_flag = alive.clone();
    let join = std::thread::Builder::new()
        .name("pjrt-executor".to_string())
        .spawn(move || {
            let _alive = AliveGuard(alive_flag);
            let mut engine = match Engine::new(manifest) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("[executor] failed to start engine: {e:#}");
                    // Drain jobs with errors so callers unblock.
                    for job in rx.iter() {
                        if refuse(job) {
                            break;
                        }
                    }
                    // Answer anything still queued behind the Stop.
                    while let Ok(job) = rx.try_recv() {
                        refuse(job);
                    }
                    return;
                }
            };
            let pool = payload_pool();
            for job in rx.iter() {
                match job {
                    Job::Eps { level, x, t, pallas, resp } => {
                        let t0 = std::time::Instant::now();
                        let r = engine.eps(level, &x, t, pallas);
                        if let Some(m) = &metrics {
                            m.execute_latency.record(t0.elapsed());
                        }
                        pool.put(x);
                        let _ = resp.send(Resp::Vec(r));
                    }
                    Job::EpsJvp { level, x, t, v, resp } => {
                        let r = engine.eps_jvp(level, &x, t, &v);
                        pool.put(x);
                        pool.put(v);
                        let _ = resp.send(Resp::Pair(r));
                    }
                    Job::Combine { y, deltas, coeffs, z, eta, sigma, pallas, resp } => {
                        let r = engine.combine(&y, &deltas, &coeffs, &z, eta, sigma, pallas);
                        pool.put(y);
                        pool.put(deltas);
                        pool.put(coeffs);
                        pool.put(z);
                        let _ = resp.send(Resp::Vec(r));
                    }
                    Job::MeasureCosts { reps, resp } => {
                        let _ = resp.send(Resp::Costs(engine.measure_costs(reps)));
                    }
                    Job::Warmup { bucket, resp } => {
                        let _ = resp.send(Resp::Unit(engine.warmup(bucket)));
                    }
                    Job::ExecStats { resp } => {
                        let (pool_hits, pool_misses) = pool.stats();
                        let _ = resp.send(Resp::Stats(Ok(ExecStats {
                            exec_calls: engine.exec_calls,
                            exec_ns: engine.exec_ns,
                            pool_hits,
                            pool_misses,
                        })));
                    }
                    Job::Stop => break,
                }
            }
            // Stop raced with queued work: answer it rather than leaving
            // callers waiting on a response that will never come.
            while let Ok(job) = rx.try_recv() {
                refuse(job);
            }
        })?;
    Ok((
        ExecutorHandle { tx, manifest: handle_manifest, alive, resp: Mutex::new(channel()) },
        join,
    ))
}

/// Copy a payload into a buffer from the executor's payload pool
/// (reused, not allocated, after warmup) for the trip to the executor
/// thread.  Multi-megabyte batch payloads are memcpy'd in parallel on
/// the worker pool ([`crate::parallel::par_copy`] shards above
/// `COPY_GRAIN`); everything smaller stays a plain wait-free
/// `copy_from_slice`.
fn pooled_copy(src: &[f32]) -> Vec<f32> {
    let mut buf = payload_pool().take_vec(src.len());
    crate::parallel::par_copy(src, &mut buf);
    buf
}

impl ExecutorHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Send one job and wait for its answer on this handle's reusable
    /// response channel.  Waiting polls the liveness flag: if the
    /// executor thread exits (Stop race, engine panic) with this request
    /// in flight, the call errors instead of hanging — the handle's own
    /// `Sender` keeps the response channel connected, so disconnect can
    /// never signal death here.
    fn call(&self, make: impl FnOnce(Sender<Resp>) -> Job) -> Result<Resp> {
        let slot = self.resp.lock().map_err(|_| anyhow!("executor handle poisoned"))?;
        self.tx.send(make(slot.0.clone())).map_err(|_| anyhow!("executor thread gone"))?;
        loop {
            match slot.1.recv_timeout(Duration::from_millis(50)) {
                Ok(r) => return Ok(r),
                Err(RecvTimeoutError::Timeout) => {
                    if !self.alive.load(Ordering::SeqCst) {
                        // One last look: the answer may have been sent
                        // just before the thread exited.
                        if let Ok(r) = slot.1.try_recv() {
                            return Ok(r);
                        }
                        return Err(anyhow!("executor thread exited with the request in flight"));
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("executor dropped response"));
                }
            }
        }
    }

    fn call_vec(&self, make: impl FnOnce(Sender<Resp>) -> Job) -> Result<Vec<f32>> {
        match self.call(make)? {
            Resp::Vec(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        }
    }

    /// Evaluate a level's eps network on a flattened `[n, dim]` batch.
    pub fn eps(&self, level: usize, x: &[f32], t: f64) -> Result<Vec<f32>> {
        let x = pooled_copy(x);
        self.call_vec(|resp| Job::Eps { level, x, t, pallas: false, resp })
    }

    /// Same through the Pallas-flavour parity artifact.
    pub fn eps_pallas(&self, level: usize, x: &[f32], t: f64) -> Result<Vec<f32>> {
        let x = pooled_copy(x);
        self.call_vec(|resp| Job::Eps { level, x, t, pallas: true, resp })
    }

    /// Evaluate (eps, ∂eps·v).
    pub fn eps_jvp(&self, level: usize, x: &[f32], t: f64, v: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let x = pooled_copy(x);
        let v = pooled_copy(v);
        match self.call(|resp| Job::EpsJvp { level, x, t, v, resp })? {
            Resp::Pair(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        }
    }

    /// Fused ML-EM combine step (see `engine::Engine::combine`).
    #[allow(clippy::too_many_arguments)]
    pub fn combine(
        &self,
        y: &[f32],
        deltas: &[f32],
        coeffs: &[f32],
        z: &[f32],
        eta: f64,
        sigma: f64,
        pallas: bool,
    ) -> Result<Vec<f32>> {
        let y = pooled_copy(y);
        let deltas = pooled_copy(deltas);
        let coeffs = pooled_copy(coeffs);
        let z = pooled_copy(z);
        self.call_vec(|resp| Job::Combine { y, deltas, coeffs, z, eta, sigma, pallas, resp })
    }

    /// Measure per-level cost in seconds/image (see engine).
    pub fn measure_costs(&self, reps: usize) -> Result<Vec<f64>> {
        match self.call(|resp| Job::MeasureCosts { reps, resp })? {
            Resp::Costs(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        }
    }

    /// Pre-compile all levels at a bucket size.
    pub fn warmup(&self, bucket: usize) -> Result<()> {
        match self.call(|resp| Job::Warmup { bucket, resp })? {
            Resp::Unit(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        }
    }

    /// Execute-call and buffer-reuse counters (see [`ExecStats`]).
    pub fn exec_stats(&self) -> Result<ExecStats> {
        match self.call(|resp| Job::ExecStats { resp })? {
            Resp::Stats(r) => r,
            _ => Err(anyhow!("executor protocol mismatch")),
        }
    }

    /// Ask the executor thread to exit.
    pub fn stop(&self) {
        let _ = self.tx.send(Job::Stop);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The payload pool is executor-local: its counters move only when
    /// request payloads do, and a put/copy cycle is a pool hit (the
    /// attribution `bench_runtime` relies on).  No other test in this
    /// binary touches `PAYLOAD_POOL`, so the deltas are deterministic.
    #[test]
    fn payload_pool_is_executor_local_and_reuses() {
        let (h0, m0) = payload_pool().stats();
        let a = pooled_copy(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, vec![1.0, 2.0, 3.0, 4.0]);
        payload_pool().put(a);
        let b = pooled_copy(&[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(b, vec![5.0, 6.0, 7.0, 8.0]);
        payload_pool().put(b);
        let (h1, m1) = payload_pool().stats();
        assert_eq!(m1 - m0, 1, "first copy allocates");
        assert_eq!(h1 - h0, 1, "second copy reuses the parked buffer");
    }
}
