//! Real-PJRT adapter compiled under the `xla` cargo feature.
//!
//! This module is the single swap point for the actual XLA bindings:
//! `engine.rs` consumes exactly this API surface, and the `xla-feature`
//! CI job (`cargo check --features xla --all-targets`) compiles it on
//! every PR so the surface can no longer rot silently while the default
//! build exercises only the offline shim.  Deployments with the vendored
//! `xla` bindings crate replace the bodies below with direct forwards
//! (`xla::PjRtClient::cpu()` etc. — the names are 1:1 by construction);
//! until then every constructor reports the missing link explicitly so a
//! feature-built binary fails loudly at startup, not by mis-serving.
//!
//! Kept separate from `xla_shim` on purpose: the shim is an *offline
//! test double* (with a synthetic-artifact interpreter), while this file
//! tracks the *real* binding contract — conflating them is how the
//! feature path rotted unnoticed before the CI job existed.

// Types exist in type position only until the bindings are linked.
#![allow(dead_code)]

use std::path::Path;

use anyhow::{anyhow, Result};

fn unlinked() -> anyhow::Error {
    anyhow!(
        "built with the `xla` feature but the PJRT bindings are not vendored; \
         forward runtime/xla_pjrt.rs to the xla bindings crate"
    )
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unlinked())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unlinked())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unlinked())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unlinked())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unlinked())
    }
}

#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_v: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unlinked())
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unlinked())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unlinked())
    }
}
