//! `mlem` — the leader binary.
//!
//! ```text
//! mlem serve      [--artifacts DIR] [--addr HOST:PORT] [--max-batch N]
//!                 [--threads T]  # sampler worker pool size (0 = auto) ...
//!                 [--batch-workers K]  # coordinator runner lanes (0 = auto: min(levels, 4))
//!                 [--exec-linger-us U] [--exec-max-group G]  # executor micro-batching
//!                 [--phase-align on|off]  # equal-step classes step behind an epoch barrier
//!                 [--hold-budget-us U]  # hold a near-full class while lanes are busy (0 = off)
//!                 [--executors N]  # executor fleet size with level-affinity placement (1 = single)
//!                 [--fleet-rebalance-every B] [--fleet-placement 5:0,1:1]  # cost-aware placement
//!                 [--trace-sample-n N]  # flight recorder: trace 1-in-N requests (0 off, 1 all)
//!                 [--trace-out PATH]  # dump Chrome trace-event JSON on shutdown
//!                 [--conn-inflight W]  # per-connection pipelining window (bounded in-flight)
//!                 [--max-conns C]  # live connection cap; excess get a typed `overloaded` line
//! mlem generate   [--n N] [--sampler em|mlem|ddpm|ddim] [--steps S] [--seed K]
//!                 [--levels 1,3,5] [--delta D] [--policy default|theory]
//!                 [--out images.pgm]
//! mlem gamma-fit  [--artifacts DIR]      # Fig-2 style γ estimate
//! mlem costs      [--artifacts DIR]      # measured per-level eval costs
//! ```

use anyhow::{anyhow, Result};

use mlem::config::{SamplerKind, ServeConfig};
use mlem::coordinator::protocol::{GenRequest, PolicyChoice};
use mlem::coordinator::{Scheduler, Server};
use mlem::metrics::Metrics;
use mlem::runtime::{Fleet, Manifest};
use mlem::util::cli::Args;
use mlem::util::stats;

fn build_scheduler(cfg: &ServeConfig) -> Result<Scheduler> {
    // Bind the --threads knob for every subcommand (generate included),
    // not just serve: the pool's size is fixed at its first use.
    cfg.apply_threads();
    let manifest = Manifest::load(&cfg.artifacts)?;
    let metrics = Metrics::new();
    // The --executors / --exec-* / --supervisor knobs bind here: the
    // fleet spawns N executor threads with the config's aggregation
    // options, each under the self-healing supervisor when
    // `--supervisor on` (the default; a dead executor thread respawns
    // from the manifest and in-flight calls are retried within the
    // `--retry-budget`).  Level-affinity placement and the cost-aware
    // rebalance cadence live in the fleet; `--executors 1` is the
    // historical single-executor runtime.
    let fleet = Fleet::spawn(manifest, Some(metrics.clone()), &cfg.fleet_options())?;
    Scheduler::with_fleet(fleet, cfg.clone(), metrics)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_args(args)?;
    let scheduler = build_scheduler(&cfg)?;
    let server = Server::new(cfg, scheduler);
    server.run(|addr| eprintln!("[mlem] ready on {addr}"))
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = ServeConfig::from_args(args)?;
    let scheduler = build_scheduler(&cfg)?;
    let req = GenRequest {
        n: args.usize_or("n", 4),
        sampler: SamplerKind::parse(&args.str_or("sampler", "mlem"))?,
        steps: args.usize_or("steps", cfg.default_steps),
        seed: args.u64_or("seed", 0),
        levels: args.usize_list("levels", &cfg.mlem_levels),
        delta: args.f64_or("delta", 0.0),
        policy: PolicyChoice::parse(&args.str_or("policy", "default"))?,
        return_images: true,
        deadline_ms: None,
        priority: 0,
    };
    let resp = scheduler.generate(&req)?;
    println!(
        "generated {} images in {:.1} ms (nfe per level: {:?}, cost {:.3})",
        req.n, resp.stats.wall_ms, resp.stats.nfe, resp.stats.cost_units
    );
    if let Some(path) = args.get("out") {
        let imgs = resp.images.as_ref().unwrap();
        write_pgm_strip(path, imgs, scheduler.handle().manifest().img, req.n)?;
        println!("wrote {path}");
    }
    scheduler.fleet().stop();
    Ok(())
}

fn cmd_gamma_fit(args: &Args) -> Result<()> {
    // Fig 2: per-level (eval time, denoising error − floor) log–log fit.
    let cfg = ServeConfig::from_args(args)?;
    let scheduler = build_scheduler(&cfg)?;
    let handle = scheduler.handle().clone();
    let m = handle.manifest();
    let losses: Vec<f64> = m.levels.iter().map(|l| l.holdout_loss).collect();
    let times = scheduler.costs.clone();
    let floor = args.f64_or("floor", estimate_floor(&losses));
    println!("level  params    time(s/img)   holdout   holdout-floor");
    for (i, l) in m.levels.iter().enumerate() {
        println!(
            "f^{}    {:7}   {:.6}      {:.4}    {:.4}",
            l.level,
            l.params,
            times[i],
            losses[i],
            losses[i] - floor
        );
    }
    let errs: Vec<f64> = losses.iter().map(|l| (l - floor).max(1e-9).sqrt()).collect();
    let fit = stats::loglog_fit(&times, &errs);
    let gamma = -1.0 / fit.slope;
    println!(
        "\nlog-log fit: eps ~ t^{:.3} (r²={:.3})  =>  gamma ≈ {:.2}  (floor {:.3})",
        fit.slope, fit.r2, gamma, floor
    );
    println!("HTMC regime (gamma > 2): {}", if gamma > 2.0 { "YES" } else { "no" });
    scheduler.fleet().stop();
    Ok(())
}

/// Pick the error floor as in the paper's Fig 2 ("chosen so the points
/// align in log-log"): grid-search the floor maximising the fit's r².
fn estimate_floor(losses: &[f64]) -> f64 {
    let min = losses.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut best = (0.0, f64::NEG_INFINITY);
    for i in 0..50 {
        let floor = min * (i as f64 / 50.0);
        let errs: Vec<f64> = losses.iter().map(|l| (l - floor).max(1e-9)).collect();
        let xs: Vec<f64> = (0..losses.len()).map(|k| 4f64.powi(k as i32)).collect();
        let fit = stats::loglog_fit(&xs, &errs);
        if fit.r2 > best.1 {
            best = (floor, fit.r2);
        }
    }
    best.0
}

fn cmd_costs(args: &Args) -> Result<()> {
    let mut cfg = ServeConfig::from_args(args)?;
    cfg.cost_reps = cfg.cost_reps.max(5);
    let scheduler = build_scheduler(&cfg)?;
    let m = scheduler.handle().manifest();
    println!("level  params    flops/img   measured s/img   ratio to f^1");
    for (i, l) in m.levels.iter().enumerate() {
        println!(
            "f^{}    {:7}   {:9}   {:.6}        {:.2}x",
            l.level,
            l.params,
            l.flops_per_image,
            scheduler.costs[i],
            scheduler.costs[i] / scheduler.costs[0]
        );
    }
    scheduler.fleet().stop();
    Ok(())
}

/// Write `n` images side by side as a binary PGM strip (quick eyeball).
fn write_pgm_strip(path: &str, imgs: &[f32], img: usize, n: usize) -> Result<()> {
    let w = img * n;
    let mut data = Vec::with_capacity(w * img);
    for row in 0..img {
        for i in 0..n {
            for col in 0..img {
                let v = imgs[i * img * img + row * img + col];
                data.push((((v + 1.0) / 2.0).clamp(0.0, 1.0) * 255.0) as u8);
            }
        }
    }
    let mut out = format!("P5\n{w} {img}\n255\n").into_bytes();
    out.extend_from_slice(&data);
    std::fs::write(path, out)?;
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("gamma-fit") => cmd_gamma_fit(&args),
        Some("costs") => cmd_costs(&args),
        other => {
            eprintln!(
                "mlem — Multilevel Euler-Maruyama diffusion serving\n\
                 usage: mlem <serve|generate|gamma-fit|costs> [flags; see rust/src/main.rs]"
            );
            if let Some(o) = other {
                Err(anyhow!("unknown command '{o}'"))
            } else {
                Ok(())
            }
        }
    }
}
