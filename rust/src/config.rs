//! Typed configuration for the `mlem` binary and the serving coordinator.
//!
//! Sources, in increasing precedence: built-in defaults → JSON config
//! file (`--config path`) → CLI flags.  The struct is deliberately
//! flat; the JSON surface additionally accepts nested `"executor"` and
//! `"fleet"` sections that alias the flat `exec_*`/fleet keys (both
//! spellings stay valid — the nested form groups the knobs the way the
//! runtime consumes them).  Every field is documented where a paper
//! parameter corresponds to it.

use anyhow::{anyhow, Result};

use crate::util::cli::Args;
use crate::util::json::Json;

/// Which sampler a generation request uses.  `Hash` because the kind is
/// part of the batcher's per-class queue key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SamplerKind {
    /// Plain Euler–Maruyama with one chosen level (the baseline).
    Em,
    /// Multilevel Euler–Maruyama (the paper's method).
    Mlem,
    /// Exact ancestral DDPM update.
    Ddpm,
    /// Deterministic DDIM update.
    Ddim,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<SamplerKind> {
        match s {
            "em" => Ok(SamplerKind::Em),
            "mlem" => Ok(SamplerKind::Mlem),
            "ddpm" => Ok(SamplerKind::Ddpm),
            "ddim" => Ok(SamplerKind::Ddim),
            _ => Err(anyhow!("unknown sampler '{s}' (em|mlem|ddpm|ddim)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SamplerKind::Em => "em",
            SamplerKind::Mlem => "mlem",
            SamplerKind::Ddpm => "ddpm",
            SamplerKind::Ddim => "ddim",
        }
    }
}

/// Serving configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Artifact directory (manifest + HLO files).
    pub artifacts: String,
    /// TCP listen address.
    pub addr: String,
    /// Maximum images per generation batch (paper used N=200 on GPU; we
    /// default to the largest exported bucket).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before dispatching.
    pub max_wait_ms: u64,
    /// Bounded request-queue size (backpressure: reject beyond this).
    pub queue_depth: usize,
    /// Default sampler for requests that don't specify one.
    pub default_sampler: SamplerKind,
    /// Default number of discretisation steps.
    pub default_steps: usize,
    /// ML-EM level subset, 1-based (paper: {f^1, f^3, f^5}).
    pub mlem_levels: Vec<usize>,
    /// Fixed-probs scale constant C (`p_k = min(C/T_k, 1)` by default).
    pub prob_scale: f64,
    /// Repetitions for startup cost measurement (0 = use FLOP estimates).
    pub cost_reps: usize,
    /// Online γ-calibration: probe every Nth batch (0 disables the
    /// whole subsystem; see `calibrate`).
    pub calib_sample_every: usize,
    /// Refit γ̂ after this many fresh probes (drift can refit earlier).
    pub calib_refit_every: usize,
    /// Autopilot compute budget: expected per-image per-step cost units
    /// for the derived policy.  0 = auto (match the baseline inverse-cost
    /// policy's spend).  Also settable live via the `calibration` admin
    /// request's `set_budget`.
    pub calib_budget: f64,
    /// Swap the calibrated `FixedTheory` policy into live serving once
    /// fitted; false = observe-and-report only.
    pub calib_autopilot: bool,
    /// Executor micro-batching: how long (µs) the executor may hold an
    /// eps/jvp job to let more same-(level, bucket, t) jobs arrive and
    /// share one device dispatch.  The window only opens when a
    /// groupable peer is already queued and no unrelated job is, so
    /// solo-request latency is unchanged and non-peer jobs are never
    /// stalled; 0 (default) groups only work that is concurrently in
    /// flight.  See `runtime::executor::ExecOptions`.
    pub exec_linger_us: u64,
    /// Executor micro-batching: maximum jobs fused into one grouped
    /// device dispatch; 1 disables grouping entirely.
    pub exec_max_group: usize,
    /// Concurrent batch-runner lanes in the coordinator: how many
    /// batches (of *different* compatibility classes — same-class
    /// batches stay serialized) may be inside `Scheduler::execute` at
    /// once, keeping the executor's cross-request grouping loop fed.
    /// 0 = auto: `min(len(mlem_levels), 4)`.  1 reproduces the
    /// historical single-worker coordinator.
    pub batch_workers: usize,
    /// Sampler worker threads (the `PALLAS_THREADS` knob as config):
    /// 0 = auto (env var if set, else the machine's parallelism).  A
    /// positive value is exported to `PALLAS_THREADS` by
    /// [`ServeConfig::apply_threads`] *before* the persistent worker
    /// pool fixes its size at first use — so it both shapes shard
    /// counts and sizes the pool, in either direction.
    pub threads: usize,
    /// Run the executor under the supervisor (respawn + replay on
    /// transport death); false = historical fail-fast executor.
    pub supervisor: bool,
    /// Supervisor: maximum respawn-and-replay attempts per request
    /// before the transport error is surfaced.
    pub retry_budget: usize,
    /// Supervisor: base backoff (µs) before a replay; attempt k sleeps
    /// `base << k`, capped at 100 ms.
    pub retry_backoff_us: u64,
    /// Admission control: shed a deadline-bearing request when its
    /// estimated completion time exceeds `deadline_ms × shed_headroom`.
    /// >1 sheds later (optimistic), <1 sheds earlier (conservative).
    pub shed_headroom: f64,
    /// Liveness-poll period (µs) while a caller waits on the executor —
    /// the bound on stop/join latency after executor death.
    pub exec_poll_us: u64,
    /// Per-connection pipelining window: how many requests one
    /// connection may have in flight (written but not yet answered) at
    /// once.  The reader thread parses and submits ahead while the
    /// writer streams responses back in request order; 1 reproduces the
    /// historical one-at-a-time handler.
    pub conn_inflight: usize,
    /// Maximum concurrent connections.  At the cap the acceptor answers
    /// the new connection with one typed `overloaded` line and closes it
    /// instead of spawning a handler.
    pub max_conns: usize,
    /// Fleet size: number of executors (each its own device thread +
    /// grouping loop) with level-affinity placement across them.  1 =
    /// the historical single-executor runtime.  See `runtime::fleet`.
    pub executors: usize,
    /// Cost-aware rebalance cadence: recompute level→executor placement
    /// from the calibrator's T̂_k every this many batches (0 = cadence
    /// off; the `{"cmd":"fleet","rebalance":true}` admin request still
    /// works).
    pub fleet_rebalance_every: u64,
    /// Explicit placement pins `(ladder level, executor index)` that
    /// override the cost-aware plan; CLI spelling `--fleet-placement
    /// 5:0,1:1`.  Levels must exist in `mlem_levels`, executor indices
    /// must be < `executors`.
    pub fleet_placement: Vec<(usize, usize)>,
    /// Cross-class phase alignment: classes with equal step counts step
    /// behind a lightweight epoch barrier so their per-t executor jobs
    /// arrive in the same linger window by construction instead of by
    /// luck.  Timing-only — outputs are bit-identical either way.  See
    /// `coordinator::phase`.
    pub phase_align: bool,
    /// Lane-aware batch holding: when all other lanes are busy, hold a
    /// near-full class for up to this many µs (further bounded by the
    /// measured EWMA batch wall time and by the oldest member's
    /// `deadline_ms` headroom) so the next cut is fuller.  0 = holding
    /// off (the historical cut-immediately behaviour).
    pub hold_budget_us: u64,
    /// Flight recorder head sampling: trace 1 request in N end to end
    /// (0 = tracing off, 1 = every request).  See `crate::trace`.
    pub trace_sample_n: usize,
    /// Dump the flight recorder's spans as Chrome trace-event JSON to
    /// this path when the server shuts down (loads in Perfetto /
    /// `chrome://tracing`); empty = no dump.
    pub trace_out: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts: "artifacts".to_string(),
            addr: "127.0.0.1:7071".to_string(),
            max_batch: 32,
            max_wait_ms: 20,
            queue_depth: 256,
            default_sampler: SamplerKind::Mlem,
            default_steps: 200,
            mlem_levels: vec![1, 3, 5],
            prob_scale: 1.0,
            cost_reps: 3,
            calib_sample_every: 16,
            calib_refit_every: 8,
            calib_budget: 0.0,
            calib_autopilot: true,
            exec_linger_us: 0,
            exec_max_group: 16,
            batch_workers: 0,
            threads: 0,
            supervisor: true,
            retry_budget: 5,
            retry_backoff_us: 500,
            shed_headroom: 1.0,
            exec_poll_us: 50_000,
            conn_inflight: 8,
            max_conns: 256,
            executors: 1,
            fleet_rebalance_every: 64,
            fleet_placement: Vec::new(),
            phase_align: true,
            hold_budget_us: 0,
            trace_sample_n: 16,
            trace_out: None,
        }
    }
}

impl ServeConfig {
    /// Apply a JSON config object (unknown keys rejected to catch typos).
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let Json::Obj(fields) = j else {
            return Err(anyhow!("config root must be an object"));
        };
        for (k, v) in fields {
            match k.as_str() {
                "artifacts" => self.artifacts = v.as_str().ok_or_else(|| anyhow!("artifacts: string"))?.into(),
                "addr" => self.addr = v.as_str().ok_or_else(|| anyhow!("addr: string"))?.into(),
                "max_batch" => self.max_batch = v.as_usize().ok_or_else(|| anyhow!("max_batch: int"))?,
                "max_wait_ms" => self.max_wait_ms = v.as_f64().ok_or_else(|| anyhow!("max_wait_ms: num"))? as u64,
                "queue_depth" => self.queue_depth = v.as_usize().ok_or_else(|| anyhow!("queue_depth: int"))?,
                "default_sampler" => {
                    self.default_sampler =
                        SamplerKind::parse(v.as_str().ok_or_else(|| anyhow!("default_sampler: string"))?)?
                }
                "default_steps" => self.default_steps = v.as_usize().ok_or_else(|| anyhow!("default_steps: int"))?,
                "mlem_levels" => {
                    self.mlem_levels = v
                        .as_arr()
                        .ok_or_else(|| anyhow!("mlem_levels: array"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect()
                }
                "prob_scale" => self.prob_scale = v.as_f64().ok_or_else(|| anyhow!("prob_scale: num"))?,
                "cost_reps" => self.cost_reps = v.as_usize().ok_or_else(|| anyhow!("cost_reps: int"))?,
                "calib_sample_every" => {
                    self.calib_sample_every =
                        v.as_usize().ok_or_else(|| anyhow!("calib_sample_every: int"))?
                }
                "calib_refit_every" => {
                    self.calib_refit_every =
                        v.as_usize().ok_or_else(|| anyhow!("calib_refit_every: int"))?
                }
                "calib_budget" => {
                    self.calib_budget = v.as_f64().ok_or_else(|| anyhow!("calib_budget: num"))?
                }
                "calib_autopilot" => {
                    self.calib_autopilot =
                        v.as_bool().ok_or_else(|| anyhow!("calib_autopilot: bool"))?
                }
                "exec_linger_us" => {
                    self.exec_linger_us =
                        v.as_usize().ok_or_else(|| anyhow!("exec_linger_us: int"))? as u64
                }
                "exec_max_group" => {
                    self.exec_max_group =
                        v.as_usize().ok_or_else(|| anyhow!("exec_max_group: int"))?
                }
                "batch_workers" => {
                    self.batch_workers =
                        v.as_usize().ok_or_else(|| anyhow!("batch_workers: int"))?
                }
                "threads" => self.threads = v.as_usize().ok_or_else(|| anyhow!("threads: int"))?,
                "supervisor" => {
                    self.supervisor = v.as_bool().ok_or_else(|| anyhow!("supervisor: bool"))?
                }
                "retry_budget" => {
                    self.retry_budget = v.as_usize().ok_or_else(|| anyhow!("retry_budget: int"))?
                }
                "retry_backoff_us" => {
                    self.retry_backoff_us =
                        v.as_usize().ok_or_else(|| anyhow!("retry_backoff_us: int"))? as u64
                }
                "shed_headroom" => {
                    self.shed_headroom = v.as_f64().ok_or_else(|| anyhow!("shed_headroom: num"))?
                }
                "exec_poll_us" => {
                    self.exec_poll_us =
                        v.as_usize().ok_or_else(|| anyhow!("exec_poll_us: int"))? as u64
                }
                "conn_inflight" => {
                    self.conn_inflight =
                        v.as_usize().ok_or_else(|| anyhow!("conn_inflight: int"))?
                }
                "max_conns" => {
                    self.max_conns = v.as_usize().ok_or_else(|| anyhow!("max_conns: int"))?
                }
                "executors" => {
                    self.executors = v.as_usize().ok_or_else(|| anyhow!("executors: int"))?
                }
                "fleet_rebalance_every" => {
                    self.fleet_rebalance_every =
                        v.as_usize().ok_or_else(|| anyhow!("fleet_rebalance_every: int"))? as u64
                }
                "fleet_placement" => self.fleet_placement = placement_from_json(v)?,
                // Nested alias sections: the same knobs grouped the way
                // the runtime consumes them.  Flat keys stay valid;
                // later keys win within one object either way.
                "executor" => {
                    let Json::Obj(sub) = v else {
                        return Err(anyhow!("executor: object"));
                    };
                    for (sk, sv) in sub {
                        match sk.as_str() {
                            "linger_us" => {
                                self.exec_linger_us =
                                    sv.as_usize().ok_or_else(|| anyhow!("executor.linger_us: int"))? as u64
                            }
                            "max_group" => {
                                self.exec_max_group =
                                    sv.as_usize().ok_or_else(|| anyhow!("executor.max_group: int"))?
                            }
                            "poll_us" => {
                                self.exec_poll_us =
                                    sv.as_usize().ok_or_else(|| anyhow!("executor.poll_us: int"))? as u64
                            }
                            other => return Err(anyhow!("unknown config key 'executor.{other}'")),
                        }
                    }
                }
                "fleet" => {
                    let Json::Obj(sub) = v else {
                        return Err(anyhow!("fleet: object"));
                    };
                    for (sk, sv) in sub {
                        match sk.as_str() {
                            "executors" => {
                                self.executors =
                                    sv.as_usize().ok_or_else(|| anyhow!("fleet.executors: int"))?
                            }
                            "rebalance_every" => {
                                self.fleet_rebalance_every = sv
                                    .as_usize()
                                    .ok_or_else(|| anyhow!("fleet.rebalance_every: int"))?
                                    as u64
                            }
                            "placement" => self.fleet_placement = placement_from_json(sv)?,
                            other => return Err(anyhow!("unknown config key 'fleet.{other}'")),
                        }
                    }
                }
                "phase_align" => {
                    self.phase_align = v.as_bool().ok_or_else(|| anyhow!("phase_align: bool"))?
                }
                "hold_budget_us" => {
                    self.hold_budget_us =
                        v.as_usize().ok_or_else(|| anyhow!("hold_budget_us: int"))? as u64
                }
                "trace_sample_n" => {
                    self.trace_sample_n =
                        v.as_usize().ok_or_else(|| anyhow!("trace_sample_n: int"))?
                }
                "trace_out" => {
                    self.trace_out =
                        Some(v.as_str().ok_or_else(|| anyhow!("trace_out: string"))?.into())
                }
                other => return Err(anyhow!("unknown config key '{other}'")),
            }
        }
        Ok(())
    }

    /// Build from defaults + optional `--config file` + CLI overrides.
    pub fn from_args(args: &Args) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("reading config {path}: {e}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow!("parsing config {path}: {e}"))?;
            cfg.apply_json(&j)?;
        }
        cfg.artifacts = args.str_or("artifacts", &cfg.artifacts);
        cfg.addr = args.str_or("addr", &cfg.addr);
        cfg.max_batch = args.usize_or("max-batch", cfg.max_batch);
        cfg.max_wait_ms = args.u64_or("max-wait-ms", cfg.max_wait_ms);
        cfg.queue_depth = args.usize_or("queue-depth", cfg.queue_depth);
        if let Some(s) = args.get("sampler") {
            cfg.default_sampler = SamplerKind::parse(s)?;
        }
        cfg.default_steps = args.usize_or("steps", cfg.default_steps);
        cfg.mlem_levels = args.usize_list("mlem-levels", &cfg.mlem_levels);
        cfg.prob_scale = args.f64_or("prob-scale", cfg.prob_scale);
        cfg.cost_reps = args.usize_or("cost-reps", cfg.cost_reps);
        cfg.calib_sample_every = args.usize_or("calib-sample-every", cfg.calib_sample_every);
        cfg.calib_refit_every = args.usize_or("calib-refit-every", cfg.calib_refit_every);
        cfg.calib_budget = args.f64_or("calib-budget", cfg.calib_budget);
        if let Some(v) = args.get("calib-autopilot") {
            cfg.calib_autopilot = match v {
                "1" | "true" | "on" => true,
                "0" | "false" | "off" => false,
                other => return Err(anyhow!("--calib-autopilot expects on|off, got '{other}'")),
            };
        }
        cfg.exec_linger_us = args.u64_or("exec-linger-us", cfg.exec_linger_us);
        cfg.exec_max_group = args.usize_or("exec-max-group", cfg.exec_max_group);
        cfg.batch_workers = args.usize_or("batch-workers", cfg.batch_workers);
        cfg.threads = args.usize_or("threads", cfg.threads);
        if let Some(v) = args.get("supervisor") {
            cfg.supervisor = match v {
                "1" | "true" | "on" => true,
                "0" | "false" | "off" => false,
                other => return Err(anyhow!("--supervisor expects on|off, got '{other}'")),
            };
        }
        cfg.retry_budget = args.usize_or("retry-budget", cfg.retry_budget);
        cfg.retry_backoff_us = args.u64_or("retry-backoff-us", cfg.retry_backoff_us);
        cfg.shed_headroom = args.f64_or("shed-headroom", cfg.shed_headroom);
        cfg.exec_poll_us = args.u64_or("exec-poll-us", cfg.exec_poll_us);
        cfg.conn_inflight = args.usize_or("conn-inflight", cfg.conn_inflight);
        cfg.max_conns = args.usize_or("max-conns", cfg.max_conns);
        cfg.executors = args.usize_or("executors", cfg.executors);
        cfg.fleet_rebalance_every = args.u64_or("fleet-rebalance-every", cfg.fleet_rebalance_every);
        if let Some(s) = args.get("fleet-placement") {
            cfg.fleet_placement = placement_from_cli(s)?;
        }
        if let Some(v) = args.get("phase-align") {
            cfg.phase_align = match v {
                "1" | "true" | "on" => true,
                "0" | "false" | "off" => false,
                other => return Err(anyhow!("--phase-align expects on|off, got '{other}'")),
            };
        }
        cfg.hold_budget_us = args.u64_or("hold-budget-us", cfg.hold_budget_us);
        cfg.trace_sample_n = args.usize_or("trace-sample-n", cfg.trace_sample_n);
        if let Some(path) = args.get("trace-out") {
            cfg.trace_out = Some(path.to_string());
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// The batch-runner lane count the coordinator actually spawns:
    /// the knob when positive, else `min(len(mlem_levels), 4)` — the
    /// level count bounds how much same-t executor traffic distinct
    /// classes can overlap, and past a handful of lanes the device
    /// thread is the bottleneck anyway.
    pub fn effective_batch_workers(&self) -> usize {
        if self.batch_workers > 0 {
            self.batch_workers
        } else {
            self.mlem_levels.len().clamp(1, 4)
        }
    }

    /// The executor aggregation knobs as the runtime consumes them.
    pub fn exec_options(&self) -> crate::runtime::ExecOptions {
        crate::runtime::ExecOptions {
            linger_us: self.exec_linger_us,
            max_group: self.exec_max_group.max(1),
            poll_interval_us: self.exec_poll_us.max(1),
        }
    }

    /// The supervision knobs as the runtime consumes them.
    pub fn supervisor_options(&self) -> crate::runtime::SupervisorOptions {
        crate::runtime::SupervisorOptions {
            retry_budget: self.retry_budget,
            retry_backoff_us: self.retry_backoff_us,
        }
    }

    /// The fleet shape as the runtime consumes it — size, per-member
    /// executor options, supervision (following the `supervisor` knob),
    /// rebalance cadence, and placement pins.
    pub fn fleet_options(&self) -> crate::runtime::FleetOptions {
        crate::runtime::FleetOptions {
            executors: self.executors.max(1),
            exec: self.exec_options(),
            supervise: self.supervisor.then(|| self.supervisor_options()),
            rebalance_every: self.fleet_rebalance_every,
            pins: self.fleet_placement.clone(),
        }
    }

    /// Fix the sampler worker pool under the `threads` knob: export a
    /// positive value to `PALLAS_THREADS` (the env var a bare-env
    /// deployment would set), then spin the pool up now so its size is
    /// decided here and not by whatever work arrives first.  Called by
    /// `Server::new` and the `mlem` binary's scheduler bootstrap, so the
    /// flag binds for every subcommand (serve, generate, …).  The pool's
    /// size is frozen at its first use process-wide; a later conflicting
    /// request can still reshape shard counts but not the pool, so it is
    /// reported instead of silently half-applying.
    pub fn apply_threads(&self) {
        if self.threads > 0 {
            if let Some(workers) = crate::parallel::pool_size() {
                if workers + 1 != self.threads {
                    eprintln!(
                        "[config] threads={} requested, but the worker pool already started \
                         with {} workers (+ the calling thread) and cannot be resized; \
                         shard counts follow the new value",
                        self.threads, workers
                    );
                }
            }
            std::env::set_var(crate::parallel::THREADS_ENV, self.threads.to_string());
        }
        crate::parallel::ensure_started();
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 || self.queue_depth == 0 || self.default_steps == 0 {
            return Err(anyhow!("max_batch, queue_depth, default_steps must be positive"));
        }
        if self.mlem_levels.is_empty() {
            return Err(anyhow!("mlem_levels must not be empty"));
        }
        // Sanity cap: a typo'd huge value would otherwise panic at boot
        // when the pool tries to spawn that many OS threads.
        if self.threads > 1024 {
            return Err(anyhow!("threads: {} exceeds the sanity cap (1024; 0=auto)", self.threads));
        }
        if self.exec_max_group == 0 {
            return Err(anyhow!("exec_max_group must be >= 1 (1 disables grouping)"));
        }
        // A typo'd huge lane count would spawn that many OS threads and
        // thrash the (single) executor for nothing.
        if self.batch_workers > 64 {
            return Err(anyhow!(
                "batch_workers: {} exceeds the sanity cap (64; 0=auto)",
                self.batch_workers
            ));
        }
        // A linger window is sub-millisecond territory; a typo'd huge
        // value would stall every grouped dispatch behind it.
        if self.exec_linger_us > 1_000_000 {
            return Err(anyhow!(
                "exec_linger_us: {} exceeds the sanity cap (1s)",
                self.exec_linger_us
            ));
        }
        // A hold is a fraction of one batch wall time; a typo'd huge
        // value would park every near-full batch behind it.
        if self.hold_budget_us > 1_000_000 {
            return Err(anyhow!(
                "hold_budget_us: {} exceeds the sanity cap (1s)",
                self.hold_budget_us
            ));
        }
        let mut sorted = self.mlem_levels.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted != self.mlem_levels {
            return Err(anyhow!("mlem_levels must be strictly increasing"));
        }
        // Liveness poll: 0 would spin a caller thread; >1s would make
        // stop/join latency worse than the historical hard-coded 50 ms.
        if self.exec_poll_us == 0 || self.exec_poll_us > 1_000_000 {
            return Err(anyhow!(
                "exec_poll_us: {} outside the sane range [1, 1000000]",
                self.exec_poll_us
            ));
        }
        // A huge retry budget would hide a permanently dead device
        // behind minutes of respawn loops.
        if self.retry_budget > 100 {
            return Err(anyhow!(
                "retry_budget: {} exceeds the sanity cap (100)",
                self.retry_budget
            ));
        }
        if self.retry_backoff_us > 1_000_000 {
            return Err(anyhow!(
                "retry_backoff_us: {} exceeds the sanity cap (1s)",
                self.retry_backoff_us
            ));
        }
        if !self.shed_headroom.is_finite() || self.shed_headroom <= 0.0 || self.shed_headroom > 100.0
        {
            return Err(anyhow!(
                "shed_headroom: {} outside the sane range (0, 100]",
                self.shed_headroom
            ));
        }
        // 0 in-flight would deadlock every connection; a huge window is
        // a memory cap typo (each slot can hold a full image payload).
        if self.conn_inflight == 0 || self.conn_inflight > 1024 {
            return Err(anyhow!(
                "conn_inflight: {} outside the sane range [1, 1024]",
                self.conn_inflight
            ));
        }
        // Each connection costs two OS threads; past a few thousand the
        // box is dying to a typo, not serving traffic.
        if self.max_conns == 0 || self.max_conns > 16_384 {
            return Err(anyhow!(
                "max_conns: {} outside the sane range [1, 16384]",
                self.max_conns
            ));
        }
        // Each executor is a device thread owning its own executable
        // cache; a typo'd huge fleet would exhaust memory at boot.
        if self.executors == 0 || self.executors > 16 {
            return Err(anyhow!(
                "executors: {} outside the sane range [1, 16]",
                self.executors
            ));
        }
        for &(level, member) in &self.fleet_placement {
            if !self.mlem_levels.contains(&level) {
                return Err(anyhow!(
                    "fleet_placement: level {level} is not in mlem_levels {:?}",
                    self.mlem_levels
                ));
            }
            if member >= self.executors {
                return Err(anyhow!(
                    "fleet_placement: executor {member} out of range (executors = {})",
                    self.executors
                ));
            }
        }
        Ok(())
    }
}

/// Placement pins from JSON: an array of `[level, executor]` pairs.
fn placement_from_json(v: &Json) -> Result<Vec<(usize, usize)>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("fleet placement: array of [level, executor] pairs"))?;
    let mut out = Vec::with_capacity(arr.len());
    for p in arr {
        let pair = p
            .as_arr()
            .filter(|a| a.len() == 2)
            .ok_or_else(|| anyhow!("fleet placement entry: [level, executor]"))?;
        let level = pair[0].as_usize().ok_or_else(|| anyhow!("fleet placement level: int"))?;
        let member = pair[1].as_usize().ok_or_else(|| anyhow!("fleet placement executor: int"))?;
        out.push((level, member));
    }
    Ok(out)
}

/// Placement pins from the CLI: `level:executor` pairs, comma-separated
/// (`--fleet-placement 5:0,1:1`).
fn placement_from_cli(s: &str) -> Result<Vec<(usize, usize)>> {
    s.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let (l, m) = p
                .split_once(':')
                .ok_or_else(|| anyhow!("--fleet-placement expects level:executor pairs, got '{p}'"))?;
            let level: usize = l.trim().parse().map_err(|_| anyhow!("--fleet-placement level: int, got '{l}'"))?;
            let member: usize = m.trim().parse().map_err(|_| anyhow!("--fleet-placement executor: int, got '{m}'"))?;
            Ok((level, member))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_validate() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let cfg = ServeConfig::from_args(&args(
            "serve --max-batch 8 --sampler em --mlem-levels 1,2,3 --prob-scale 0.5",
        ))
        .unwrap();
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.default_sampler, SamplerKind::Em);
        assert_eq!(cfg.mlem_levels, vec![1, 2, 3]);
        assert!((cfg.prob_scale - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_config_applies_and_rejects_unknown() {
        let mut cfg = ServeConfig::default();
        let j = Json::parse(r#"{"max_batch": 16, "default_sampler": "ddim"}"#).unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.default_sampler, SamplerKind::Ddim);
        let bad = Json::parse(r#"{"max_batsch": 16}"#).unwrap();
        assert!(cfg.apply_json(&bad).is_err());
    }

    #[test]
    fn bad_levels_rejected() {
        assert!(ServeConfig::from_args(&args("serve --mlem-levels 3,1")).is_err());
        assert!(ServeConfig::from_args(&args("serve --mlem-levels 1,1,2")).is_err());
    }

    #[test]
    fn calibration_config_keys_apply() {
        let mut cfg = ServeConfig::default();
        let j = Json::parse(
            r#"{"calib_sample_every":4,"calib_refit_every":2,"calib_budget":3.5,"calib_autopilot":false}"#,
        )
        .unwrap();
        cfg.apply_json(&j).unwrap();
        assert_eq!(cfg.calib_sample_every, 4);
        assert_eq!(cfg.calib_refit_every, 2);
        assert!((cfg.calib_budget - 3.5).abs() < 1e-12);
        assert!(!cfg.calib_autopilot);
        let cli = ServeConfig::from_args(&args(
            "serve --calib-sample-every 2 --calib-autopilot off --calib-budget 1.25",
        ))
        .unwrap();
        assert_eq!(cli.calib_sample_every, 2);
        assert!(!cli.calib_autopilot);
        assert!((cli.calib_budget - 1.25).abs() < 1e-12);
        assert!(ServeConfig::from_args(&args("serve --calib-autopilot maybe")).is_err());
    }

    #[test]
    fn threads_knob_applies() {
        assert_eq!(ServeConfig::default().threads, 0, "auto by default");
        let cli = ServeConfig::from_args(&args("serve --threads 6")).unwrap();
        assert_eq!(cli.threads, 6);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"threads": 3}"#).unwrap()).unwrap();
        assert_eq!(cfg.threads, 3);
        // typo protection: absurd values are a config error, not a
        // thread-spawn panic at boot
        assert!(ServeConfig::from_args(&args("serve --threads 1000000")).is_err());
    }

    #[test]
    fn exec_batching_knobs_apply() {
        let d = ServeConfig::default();
        assert_eq!(d.exec_linger_us, 0, "no added latency by default");
        assert!(d.exec_max_group > 1, "grouping on by default");
        assert_eq!(d.exec_options().max_group, d.exec_max_group);
        let cli = ServeConfig::from_args(&args("serve --exec-linger-us 250 --exec-max-group 4"))
            .unwrap();
        assert_eq!(cli.exec_linger_us, 250);
        assert_eq!(cli.exec_max_group, 4);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"exec_linger_us": 50, "exec_max_group": 1}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.exec_linger_us, 50);
        assert_eq!(cfg.exec_max_group, 1, "1 = grouping off, still valid");
        cfg.validate().unwrap();
        assert!(ServeConfig::from_args(&args("serve --exec-max-group 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --exec-linger-us 2000000")).is_err());
    }

    #[test]
    fn batch_workers_knob_applies() {
        let d = ServeConfig::default();
        assert_eq!(d.batch_workers, 0, "auto by default");
        assert_eq!(d.effective_batch_workers(), 3, "min(|{{1,3,5}}|, 4)");
        let cli = ServeConfig::from_args(&args("serve --batch-workers 2")).unwrap();
        assert_eq!(cli.batch_workers, 2);
        assert_eq!(cli.effective_batch_workers(), 2);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"batch_workers": 1}"#).unwrap()).unwrap();
        assert_eq!(cfg.effective_batch_workers(), 1, "1 = historical single worker");
        cfg.batch_workers = 0;
        cfg.mlem_levels = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(cfg.effective_batch_workers(), 4, "auto caps at 4");
        cfg.mlem_levels = vec![2];
        assert_eq!(cfg.effective_batch_workers(), 1);
        assert!(ServeConfig::from_args(&args("serve --batch-workers 1000")).is_err());
    }

    #[test]
    fn resilience_knobs_apply() {
        let d = ServeConfig::default();
        assert!(d.supervisor, "supervision on by default");
        assert_eq!(d.retry_budget, 5);
        assert_eq!(d.retry_backoff_us, 500);
        assert!((d.shed_headroom - 1.0).abs() < 1e-12);
        assert_eq!(d.exec_poll_us, 50_000, "historical 50 ms poll by default");
        assert_eq!(d.exec_options().poll_interval_us, d.exec_poll_us);
        assert_eq!(d.supervisor_options().retry_budget, d.retry_budget);
        assert_eq!(d.supervisor_options().retry_backoff_us, d.retry_backoff_us);
        let cli = ServeConfig::from_args(&args(
            "serve --supervisor off --retry-budget 2 --retry-backoff-us 100 \
             --shed-headroom 1.5 --exec-poll-us 200",
        ))
        .unwrap();
        assert!(!cli.supervisor);
        assert_eq!(cli.retry_budget, 2);
        assert_eq!(cli.retry_backoff_us, 100);
        assert!((cli.shed_headroom - 1.5).abs() < 1e-12);
        assert_eq!(cli.exec_poll_us, 200);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"supervisor":false,"retry_budget":3,"retry_backoff_us":250,
                    "shed_headroom":0.8,"exec_poll_us":1000}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert!(!cfg.supervisor);
        assert_eq!(cfg.retry_budget, 3);
        assert_eq!(cfg.retry_backoff_us, 250);
        assert!((cfg.shed_headroom - 0.8).abs() < 1e-12);
        assert_eq!(cfg.exec_poll_us, 1000);
        cfg.validate().unwrap();
        assert!(ServeConfig::from_args(&args("serve --supervisor maybe")).is_err());
        assert!(ServeConfig::from_args(&args("serve --exec-poll-us 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --exec-poll-us 2000000")).is_err());
        assert!(ServeConfig::from_args(&args("serve --retry-budget 1000")).is_err());
        assert!(ServeConfig::from_args(&args("serve --retry-backoff-us 2000000")).is_err());
        assert!(ServeConfig::from_args(&args("serve --shed-headroom 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --shed-headroom 1000")).is_err());
    }

    #[test]
    fn frontdoor_knobs_apply() {
        let d = ServeConfig::default();
        assert_eq!(d.conn_inflight, 8, "pipelining on by default");
        assert_eq!(d.max_conns, 256);
        let cli = ServeConfig::from_args(&args("serve --conn-inflight 1 --max-conns 32")).unwrap();
        assert_eq!(cli.conn_inflight, 1, "1 = historical one-at-a-time handler");
        assert_eq!(cli.max_conns, 32);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"conn_inflight": 16, "max_conns": 1024}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.conn_inflight, 16);
        assert_eq!(cfg.max_conns, 1024);
        cfg.validate().unwrap();
        assert!(ServeConfig::from_args(&args("serve --conn-inflight 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --conn-inflight 99999")).is_err());
        assert!(ServeConfig::from_args(&args("serve --max-conns 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --max-conns 99999")).is_err());
    }

    #[test]
    fn fleet_knobs_apply() {
        let d = ServeConfig::default();
        assert_eq!(d.executors, 1, "single executor by default");
        assert_eq!(d.fleet_rebalance_every, 64);
        assert!(d.fleet_placement.is_empty());
        assert_eq!(d.fleet_options().executors, 1);
        assert!(d.fleet_options().supervise.is_some(), "follows the supervisor knob");

        let cli = ServeConfig::from_args(&args(
            "serve --executors 4 --fleet-rebalance-every 8 --fleet-placement 5:0,1:1",
        ))
        .unwrap();
        assert_eq!(cli.executors, 4);
        assert_eq!(cli.fleet_rebalance_every, 8);
        assert_eq!(cli.fleet_placement, vec![(5, 0), (1, 1)]);
        assert_eq!(cli.fleet_options().pins, vec![(5, 0), (1, 1)]);

        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"executors":2,"fleet_rebalance_every":0,"fleet_placement":[[3,1]]}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.executors, 2);
        assert_eq!(cfg.fleet_rebalance_every, 0, "0 = cadence off, still valid");
        assert_eq!(cfg.fleet_placement, vec![(3, 1)]);
        cfg.validate().unwrap();
        let off = ServeConfig::from_args(&args("serve --executors 2 --supervisor off")).unwrap();
        assert!(off.fleet_options().supervise.is_none());

        // Validation: fleet size bounds, pins must reference existing
        // ladder levels and in-range executors.
        assert!(ServeConfig::from_args(&args("serve --executors 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --executors 99")).is_err());
        assert!(
            ServeConfig::from_args(&args("serve --executors 2 --fleet-placement 2:1")).is_err(),
            "level 2 is not in the default ladder {{1,3,5}}"
        );
        assert!(
            ServeConfig::from_args(&args("serve --executors 2 --fleet-placement 5:2")).is_err(),
            "executor index out of range"
        );
        assert!(ServeConfig::from_args(&args("serve --fleet-placement nonsense")).is_err());
    }

    #[test]
    fn nested_config_sections_alias_flat_keys() {
        let mut cfg = ServeConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"executor":{"linger_us":250,"max_group":4,"poll_us":1000},
                    "fleet":{"executors":4,"rebalance_every":16,"placement":[[5,0],[1,2]]}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.exec_linger_us, 250);
        assert_eq!(cfg.exec_max_group, 4);
        assert_eq!(cfg.exec_poll_us, 1000);
        assert_eq!(cfg.executors, 4);
        assert_eq!(cfg.fleet_rebalance_every, 16);
        assert_eq!(cfg.fleet_placement, vec![(5, 0), (1, 2)]);
        cfg.validate().unwrap();
        // Typos inside the nested sections are caught like flat ones.
        let mut c2 = ServeConfig::default();
        assert!(c2.apply_json(&Json::parse(r#"{"executor":{"lingr_us":1}}"#).unwrap()).is_err());
        assert!(c2.apply_json(&Json::parse(r#"{"fleet":{"executor":2}}"#).unwrap()).is_err());
        assert!(c2.apply_json(&Json::parse(r#"{"fleet":7}"#).unwrap()).is_err());
    }

    #[test]
    fn saturation_knobs_apply() {
        let d = ServeConfig::default();
        assert!(d.phase_align, "alignment on by default");
        assert_eq!(d.hold_budget_us, 0, "holding off by default");
        let cli = ServeConfig::from_args(&args("serve --phase-align off --hold-budget-us 2000"))
            .unwrap();
        assert!(!cli.phase_align);
        assert_eq!(cli.hold_budget_us, 2000);
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"phase_align": false, "hold_budget_us": 500}"#).unwrap())
            .unwrap();
        assert!(!cfg.phase_align);
        assert_eq!(cfg.hold_budget_us, 500);
        cfg.validate().unwrap();
        assert!(ServeConfig::from_args(&args("serve --phase-align maybe")).is_err());
        assert!(ServeConfig::from_args(&args("serve --hold-budget-us 2000000")).is_err());
    }

    #[test]
    fn trace_knobs_apply() {
        let d = ServeConfig::default();
        assert_eq!(d.trace_sample_n, 16, "1-in-16 head sampling by default");
        assert_eq!(d.trace_out, None);
        let cli = ServeConfig::from_args(&args("serve --trace-sample-n 1 --trace-out trace.json"))
            .unwrap();
        assert_eq!(cli.trace_sample_n, 1);
        assert_eq!(cli.trace_out.as_deref(), Some("trace.json"));
        let mut cfg = ServeConfig::default();
        cfg.apply_json(&Json::parse(r#"{"trace_sample_n": 0, "trace_out": "/tmp/t.json"}"#).unwrap())
            .unwrap();
        assert_eq!(cfg.trace_sample_n, 0, "0 = tracing off, still valid");
        assert_eq!(cfg.trace_out.as_deref(), Some("/tmp/t.json"));
        cfg.validate().unwrap();
    }

    #[test]
    fn sampler_parse_roundtrip() {
        for s in ["em", "mlem", "ddpm", "ddim"] {
            assert_eq!(SamplerKind::parse(s).unwrap().as_str(), s);
        }
        assert!(SamplerKind::parse("nope").is_err());
    }
}
