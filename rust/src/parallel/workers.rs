//! Persistent worker pool: the spawn-free execution substrate behind
//! [`super::shard::run_shards`].
//!
//! The original hot path executed each sharded batch on
//! `std::thread::scope`, paying ~10µs of thread spawn per engaged worker
//! per call — exactly the per-step overhead ML-EM amortises worst, since
//! Theorem 1's speedup comes from running *many* cheap-level steps for
//! every expensive one.  A [`WorkerPool`] instead parks long-lived
//! threads on a lightweight **epoch barrier** (std-only:
//! `Mutex`/`Condvar`): dispatching a batch is one lock + `notify_all`
//! (~1–2µs), which is what lets the engagement grains in [`super::shard`]
//! drop low enough for small batches to shard at all.
//!
//! # Determinism
//!
//! [`WorkerPool::run`] keeps the exact task semantics of the historical
//! scoped-spawn `run_shards`: the **calling thread executes task 0**
//! synchronously, parked workers execute tasks `1..n` (worker `w` takes
//! the strided set `{1+w, 1+w+W, …}` when there are more tasks than
//! workers), and the call does not return until every task has run.
//! Tasks carry disjoint `&mut` row chunks and each task's per-element
//! arithmetic is untouched, so *which* thread runs a task can never
//! change a bit of output — trajectories stay bit-identical to the
//! serial loop for every pool size (property-tested in
//! `tests/parity_parallel.rs`).
//!
//! # Lifecycle
//!
//! The process-wide pool ([`global`]) is created at its first multi-task
//! dispatch; its size is **fixed at first use** as
//! [`super::shard::num_threads`]` − 1` workers (`PALLAS_THREADS` when
//! set — smaller *or* larger than the machine — else available
//! parallelism; the caller is the extra hand).  Later `PALLAS_THREADS`
//! changes still shape shard counts per call; task counts beyond the
//! worker count are absorbed by striding.  Locally created pools shut
//! down gracefully on drop: workers observe the shutdown flag, exit
//! their park loop, and are joined.
//!
//! # Re-entrancy
//!
//! A dispatch from inside a pool task (nested parallelism) or from a
//! thread that is already mid-dispatch falls back to the inline serial
//! loop instead of deadlocking on the single shared job slot — same
//! results, no surprise.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

thread_local! {
    /// True on pool worker threads always, and on any thread while it is
    /// inside a pooled dispatch — nested [`WorkerPool::run`] calls from
    /// such threads run inline (see module docs).
    static POOL_BUSY: Cell<bool> = const { Cell::new(false) };
}

/// Type-erased "execute task `i`" callback for the current batch.  The
/// pointee lives on the submitting thread's stack; the barrier protocol
/// guarantees no worker touches it after `run` returns.
#[derive(Clone, Copy)]
struct BatchFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the raw pointer is only dereferenced by workers between batch
// publication and the completion barrier, while the pointee is alive and
// `Sync` (asserted by `WorkerPool::run`'s bounds).
unsafe impl Send for BatchFn {}

/// One published batch of tasks.
#[derive(Clone, Copy)]
struct Batch {
    run_one: BatchFn,
    tasks: usize,
}

/// Barrier state shared between the submitter and the workers.
struct State {
    /// Bumped once per published batch; workers park until it moves.
    epoch: u64,
    /// The in-flight batch (`None` between dispatches).
    batch: Option<Batch>,
    /// Participating workers that have not yet finished their share.
    remaining: usize,
    /// First panic payload caught in a worker task this batch.
    panic: Option<Box<dyn std::any::Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for the epoch to move.
    work: Condvar,
    /// The submitter parks here waiting for `remaining` to hit zero.
    done: Condvar,
}

/// Cumulative dispatch counters (process-global for the [`global`] pool;
/// per-pool otherwise).  `spawns_avoided` counts the scoped threads the
/// historical `run_shards` would have spawned for the same calls —
/// the pool's reason to exist — while `barrier_waits` counts dispatches
/// where the caller actually blocked at the completion barrier after
/// finishing its own task 0 (`barrier_wait_ns` is the time it spent
/// there).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Parked worker threads (pool size; caller thread not included).
    pub workers: usize,
    /// Multi-task dispatches that went through the barrier.
    pub runs: u64,
    /// Dispatches executed inline (nested/re-entrant calls, or a pool
    /// with zero workers).
    pub inline_runs: u64,
    /// Thread spawns the scoped-spawn path would have paid (`tasks − 1`
    /// summed over pooled dispatches).
    pub spawns_avoided: u64,
    /// Pooled dispatches where the caller blocked at the barrier.
    pub barrier_waits: u64,
    /// Cumulative nanoseconds the caller spent blocked at the barrier.
    pub barrier_wait_ns: u64,
}

#[derive(Default)]
struct Counters {
    runs: AtomicU64,
    inline_runs: AtomicU64,
    spawns_avoided: AtomicU64,
    barrier_waits: AtomicU64,
    barrier_wait_ns: AtomicU64,
}

/// A fixed-size pool of parked worker threads executing sharded batches
/// published through an epoch barrier.  See the module docs for the
/// protocol and determinism argument.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serialises submitters: one batch in flight at a time (a second
    /// top-level caller blocks here until the pool is free again).
    submit: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
    counters: Counters,
}

impl WorkerPool {
    /// Spawn `workers` parked threads.  `with_workers(0)` is a valid
    /// pool whose dispatches all run inline on the caller.
    pub fn with_workers(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                batch: None,
                remaining: 0,
                panic: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("mlem-worker-{w}"))
                    .spawn(move || worker_loop(&shared, w, workers))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool { shared, submit: Mutex::new(()), handles, counters: Counters::default() }
    }

    /// Parked worker threads (the caller thread is the `+1`).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Execute one task per entry of `tasks`; the calling thread runs
    /// task 0, parked workers run the rest, and the call returns only
    /// once every task has finished (a task panic is re-raised here).
    /// Exact drop-in for the scoped-spawn `run_shards` semantics.
    pub fn run<T, F>(&self, tasks: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(usize, T) + Sync,
    {
        let n = tasks.len();
        if n <= 1 || self.handles.is_empty() || POOL_BUSY.with(Cell::get) {
            self.counters.inline_runs.fetch_add(1, Ordering::Relaxed);
            for (i, t) in tasks.into_iter().enumerate() {
                f(i, t);
            }
            return;
        }

        // Each task is parked in a cell claimed by exactly one thread:
        // index 0 by the caller, index i ≥ 1 by worker (i − 1) % W.
        let cells: Vec<TaskCell<T>> =
            tasks.into_iter().map(|t| TaskCell(UnsafeCell::new(Some(t)))).collect();
        let run_one = |i: usize| {
            // SAFETY: disjoint claim per index (see above); the cell is
            // alive for the whole dispatch.
            let t = unsafe { (*cells[i].0.get()).take() }.expect("pool task claimed twice");
            f(i, t);
        };
        let erased: &(dyn Fn(usize) + Sync) = &run_one;
        let participants = self.handles.len().min(n - 1);

        POOL_BUSY.with(|b| b.set(true));
        let submit = self.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.batch = Some(Batch { run_one: BatchFn(erased as *const _), tasks: n });
            st.remaining = participants;
            st.panic = None;
            st.epoch += 1;
            self.shared.work.notify_all();
        }

        // The caller takes task 0 (the run_shards contract).  A panic
        // here must still wait out the barrier: workers hold pointers
        // into this stack frame until `remaining` hits zero.
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_one(0)));

        let panicked = {
            let mut st = self.shared.state.lock().unwrap();
            if st.remaining > 0 {
                let t0 = Instant::now();
                while st.remaining > 0 {
                    st = self.shared.done.wait(st).unwrap();
                }
                self.counters.barrier_waits.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .barrier_wait_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            st.batch = None;
            st.panic.take()
        };
        drop(submit);
        POOL_BUSY.with(|b| b.set(false));

        self.counters.runs.fetch_add(1, Ordering::Relaxed);
        self.counters.spawns_avoided.fetch_add((n - 1) as u64, Ordering::Relaxed);
        if let Some(p) = panicked {
            std::panic::resume_unwind(p);
        }
        if let Err(p) = mine {
            std::panic::resume_unwind(p);
        }
    }

    /// Dispatch counters since pool creation.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.handles.len(),
            runs: self.counters.runs.load(Ordering::Relaxed),
            inline_runs: self.counters.inline_runs.load(Ordering::Relaxed),
            spawns_avoided: self.counters.spawns_avoided.load(Ordering::Relaxed),
            barrier_waits: self.counters.barrier_waits.load(Ordering::Relaxed),
            barrier_wait_ns: self.counters.barrier_wait_ns.load(Ordering::Relaxed),
        }
    }
}

impl Drop for WorkerPool {
    /// Graceful shutdown: flag, wake everyone, join.
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// `Option<T>` slot claimed by exactly one thread per dispatch.
struct TaskCell<T>(UnsafeCell<Option<T>>);

// SAFETY: each cell is read/written by a single thread (disjoint static
// claim — caller: index 0, worker w: indices {1+w, 1+w+W, …}); `T: Send`
// lets the value cross from the submitting thread to that worker.
unsafe impl<T: Send> Sync for TaskCell<T> {}

fn worker_loop(shared: &Shared, w: usize, workers: usize) {
    // Workers are always "busy": a task that itself dispatches to the
    // pool must run that inner batch inline rather than deadlock.
    POOL_BUSY.with(|b| b.set(true));
    let mut seen = 0u64;
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break;
                }
                st = shared.work.wait(st).unwrap();
            }
            st.batch
        };
        // `batch` can be `None` only on a spurious epoch observation
        // after the submitter already cleared it — nothing to do.
        let Some(batch) = batch else { continue };
        if 1 + w >= batch.tasks {
            // Not a participant this round: the submitter did not count
            // us in `remaining`, so just park again.
            continue;
        }
        // SAFETY: we are a counted participant, so the submitter blocks
        // until we decrement `remaining` below — the pointee outlives
        // every dereference here.
        let run_one = unsafe { &*batch.run_one.0 };
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut i = 1 + w;
            while i < batch.tasks {
                run_one(i);
                i += workers;
            }
        }));
        let mut st = shared.state.lock().unwrap();
        if let Err(p) = caught {
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done.notify_one();
        }
    }
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool used by [`super::shard::run_shards`].  Created
/// on first call; size fixed then (see module docs).  Honouring a
/// below-machine `PALLAS_THREADS` here — not `max`ing it with the
/// hardware — is what lets an operator *bound* the sampler's thread
/// footprint; oversubscribed shard counts later just stride.
pub fn global() -> &'static WorkerPool {
    GLOBAL.get_or_init(|| WorkerPool::with_workers(super::shard::num_threads().saturating_sub(1)))
}

/// Force-create the global pool now (the serving coordinator calls this
/// after applying its `threads` config so the size is fixed under the
/// operator's knob, not whatever request arrives first).
pub fn ensure_started() {
    let _ = global();
}

/// Counters of the process-wide pool; zeros (with `workers: 0`) until
/// its first multi-task dispatch creates it.
pub fn pool_stats() -> PoolStats {
    GLOBAL.get().map(WorkerPool::stats).unwrap_or_default()
}

/// Worker count of the process-wide pool, or `None` while it has not
/// been created yet (unlike [`pool_stats`], distinguishes "not started"
/// from a started zero-worker pool — `ServeConfig::apply_threads` uses
/// this to report an unsatisfiable resize).
pub fn pool_size() -> Option<usize> {
    GLOBAL.get().map(WorkerPool::workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn executes_every_task_exactly_once_with_matching_index() {
        let pool = WorkerPool::with_workers(3);
        for n in [2usize, 3, 4, 7, 16] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            let tasks: Vec<usize> = (0..n).collect();
            pool.run(tasks, |i, t| {
                assert_eq!(i, t, "index/task mismatch");
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {n}");
            }
        }
    }

    #[test]
    fn more_tasks_than_workers_stride_correctly() {
        let pool = WorkerPool::with_workers(2);
        let n = 11;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.run((0..n).collect(), |_, t: usize| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_and_single_task_run_inline() {
        let pool = WorkerPool::with_workers(2);
        pool.run(Vec::<usize>::new(), |_, _| panic!("no tasks to run"));
        let ran = AtomicUsize::new(0);
        pool.run(vec![42usize], |i, t| {
            assert_eq!((i, t), (0, 42));
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
        let s = pool.stats();
        assert_eq!(s.runs, 0, "inline paths must not count as pooled runs");
        assert_eq!(s.inline_runs, 2);
    }

    #[test]
    fn matches_serial_loop_bitwise() {
        let pool = WorkerPool::with_workers(3);
        let dim = 5;
        let rows = 97;
        let x: Vec<f32> = (0..rows * dim).map(|i| (i as f32).sin()).collect();
        let kernel = |xc: &[f32], oc: &mut [f32]| {
            for (xb, ob) in xc.chunks_exact(dim).zip(oc.chunks_exact_mut(dim)) {
                let dot: f32 = xb.iter().map(|&v| v * v).sum();
                for j in 0..dim {
                    ob[j] = xb[j] * dot.sqrt() - 0.5;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * dim];
        kernel(&x, &mut serial);
        for shards in [2usize, 3, 4, 9] {
            let sh = crate::parallel::shards(rows, shards);
            let mut out = vec![0.0f32; rows * dim];
            let xs = crate::parallel::split_rows(&x, dim, &sh);
            let os = crate::parallel::split_rows_mut(&mut out, dim, &sh);
            let tasks: Vec<(&[f32], &mut [f32])> = xs.into_iter().zip(os).collect();
            pool.run(tasks, |_, (xc, oc)| kernel(xc, oc));
            assert!(
                serial.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{shards}-shard pool run diverged from serial"
            );
        }
    }

    #[test]
    fn repeated_small_dispatches_reuse_the_same_pool() {
        // Epoch hygiene: hundreds of back-to-back small batches through
        // one pool, every task observed exactly once per batch.
        let pool = WorkerPool::with_workers(4);
        for round in 0..300usize {
            let n = 2 + round % 6;
            let sum = AtomicUsize::new(0);
            pool.run((0..n).collect(), |_, t: usize| {
                sum.fetch_add(t + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2, "round {round}");
        }
        let s = pool.stats();
        assert_eq!(s.runs, 300);
        assert!(s.spawns_avoided >= 300, "each run avoids >= 1 spawn");
    }

    #[test]
    fn nested_dispatch_runs_inline_instead_of_deadlocking() {
        let pool = WorkerPool::with_workers(2);
        let inner_hits = AtomicUsize::new(0);
        pool.run(vec![0usize, 1, 2], |_, _| {
            // Dispatch from inside a pool task: must fall back to the
            // serial loop (POOL_BUSY), not wait on the occupied barrier.
            pool.run(vec![10usize, 11], |i, t| {
                assert_eq!(t - 10, i);
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_hits.load(Ordering::Relaxed), 6);
        assert!(pool.stats().inline_runs >= 3);
    }

    #[test]
    fn concurrent_submitters_serialise_on_the_pool() {
        let pool = WorkerPool::with_workers(2);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        pool.run(vec![1usize, 2, 3], |_, t| {
                            total.fetch_add(t, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 6);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::with_workers(3);
        pool.run(vec![0usize, 1, 2, 3], |_, _| {});
        drop(pool); // hangs (and times the test out) if shutdown is broken
    }

    #[test]
    fn worker_panic_propagates_to_the_submitter() {
        let pool = WorkerPool::with_workers(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(vec![0usize, 1, 2], |_, t| {
                if t == 2 {
                    panic!("boom in task {t}");
                }
            });
        }));
        assert!(caught.is_err(), "task panic must reach the submitter");
        // The pool must stay usable afterwards.
        let ok = AtomicUsize::new(0);
        pool.run(vec![0usize, 1], |_, _| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn stats_track_barrier_traffic() {
        let pool = WorkerPool::with_workers(2);
        pool.run(vec![0usize, 1, 2], |_, _| {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        let s = pool.stats();
        assert_eq!(s.workers, 2);
        assert_eq!(s.runs, 1);
        assert_eq!(s.spawns_avoided, 2);
        assert!(s.barrier_waits <= 1);
    }
}
