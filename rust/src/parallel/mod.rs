//! Parallel, allocation-free substrate for the sampling hot path.
//!
//! Three pieces, all dependency-free (std threads + mutexed free-lists —
//! no rayon/crossbeam offline):
//!
//! * [`shard`] — a deterministic batch sharder.  A `[batch, dim]` buffer
//!   is partitioned into contiguous *row* ranges ([`Shard`]s) that
//!   workers process independently.  The partition is a pure function of
//!   `(rows, thread count)` and every worker touches only its own rows,
//!   so results are **bit-identical** to the serial loop for any
//!   `PALLAS_THREADS` setting — parallelism never reorders a single
//!   floating-point operation within a row.
//! * [`workers`] — the persistent [`WorkerPool`]: long-lived threads
//!   parked on an epoch barrier execute the sharded tasks.  Dispatch is
//!   one lock + wake (~1–2µs) instead of the ~10µs-per-thread scoped
//!   spawn it replaced, the calling thread still takes shard 0, and the
//!   pool size is fixed at first use (`PALLAS_THREADS`, else the
//!   machine's parallelism).
//! * [`pool`] — [`ScratchPool`], a reusable free-list of scratch buffers
//!   keyed by nothing (best-fit by capacity).  Hot loops that used to
//!   allocate fresh `Vec`s per call (`Drift::jvp` central differences,
//!   `SumDrift::eval`, the executor's request payloads, `mlem_sample`'s
//!   per-run scratch) now borrow from the process-wide pools and return
//!   the buffers on drop; steady state allocates nothing.
//!
//! Thread count comes from the `PALLAS_THREADS` env knob (default: the
//! machine's available parallelism).  Two work-size grains gate when
//! extra workers are actually engaged: [`HEAVY_GRAIN`] for compute-bound
//! per-row kernels (GMM scores) and [`LIGHT_GRAIN`] for memory-bound
//! elementwise loops (fused accumulate/update).  Both dropped by 8×/4×
//! when dispatch moved from scoped spawns to the parked pool — small
//! batches shard now.

pub mod pool;
pub mod shard;
pub mod workers;

pub use pool::{global_f32, global_f64, ScratchGuard, ScratchPool};
pub use shard::{
    for_each_shard, heavy_shards, light_shards, num_threads, par_copy, par_map_rows_light,
    run_shards, run_shards_scoped, shards, split_rows, split_rows_mut, Shard, COPY_GRAIN,
    HEAVY_GRAIN, LIGHT_GRAIN, THREADS_ENV,
};
pub use workers::{ensure_started, pool_size, pool_stats, PoolStats, WorkerPool};
