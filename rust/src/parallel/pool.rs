//! Reusable scratch buffers for the sampling hot path.
//!
//! A [`ScratchPool`] is a mutex-guarded free-list of `Vec`s plus hit /
//! miss counters.  `take*` hands out a buffer of the requested length
//! (best-fit by capacity, so mixed widths coexist without churn); the
//! RAII [`ScratchGuard`] returns it on drop, and `take_vec`/`put` do the
//! same manually for buffers that must cross a thread boundary (the
//! executor's request payloads).  After warmup the hot path allocates no
//! state-width buffers per step — the `misses` counter is the measurable
//! proof (see `bench_hotpath` / `bench_runtime`).  Small bookkeeping
//! allocations (shard lists, task vectors) remain and are not pooled.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Free-list capacity: beyond this, returned buffers are simply dropped
/// (bounds worst-case memory under bursty widths).
const MAX_POOLED: usize = 64;

/// A reusable pool of `Vec<T>` scratch buffers.
pub struct ScratchPool<T: Copy + Default + Send> {
    bufs: Mutex<Vec<Vec<T>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Most buffers ever parked at once.  Bounded by the peak number of
    /// concurrent borrowers (a fresh buffer is only created when the
    /// free-list is empty, i.e. every existing buffer is live), which
    /// the concurrency stress test asserts.
    high_water: AtomicU64,
}

impl<T: Copy + Default + Send> ScratchPool<T> {
    pub const fn new() -> ScratchPool<T> {
        ScratchPool {
            bufs: Mutex::new(Vec::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
        }
    }

    /// Take a buffer of exactly `len` elements.  Contents are
    /// **unspecified** (recycled data) — overwrite before reading, or use
    /// [`ScratchPool::take_zeroed`].
    pub fn take(&self, len: usize) -> ScratchGuard<'_, T> {
        ScratchGuard { pool: self, buf: self.take_vec(len) }
    }

    /// Take a buffer of `len` elements filled with `T::default()`.
    pub fn take_zeroed(&self, len: usize) -> ScratchGuard<'_, T> {
        let mut g = self.take(len);
        g.buf.fill(T::default());
        g
    }

    /// Take a raw `Vec` (for sending across threads); pair with
    /// [`ScratchPool::put`].  Same contents caveat as `take`.
    pub fn take_vec(&self, len: usize) -> Vec<T> {
        let popped = {
            let mut bufs = self.bufs.lock().unwrap();
            // Best fit: the smallest buffer whose capacity already
            // suffices, else the largest one (it will grow the least).
            let idx = bufs
                .iter()
                .enumerate()
                .filter(|(_, b)| b.capacity() >= len)
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i)
                .or_else(|| {
                    bufs.iter().enumerate().max_by_key(|(_, b)| b.capacity()).map(|(i, _)| i)
                });
            idx.map(|i| bufs.swap_remove(i))
        };
        let mut buf = popped.unwrap_or_default();
        if buf.capacity() >= len {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            // About to reallocate anyway — clear first so resize's grow
            // path doesn't memcpy the evicted buffer's stale contents.
            buf.clear();
        }
        buf.resize(len, T::default());
        buf
    }

    /// Return a buffer to the free-list (dropped when the list is full).
    pub fn put(&self, buf: Vec<T>) {
        if buf.capacity() == 0 {
            return;
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < MAX_POOLED {
            bufs.push(buf);
            self.high_water.fetch_max(bufs.len() as u64, Ordering::Relaxed);
        }
    }

    /// `(hits, misses)`: takes served from the free-list vs takes that
    /// had to allocate (or grow).  Steady-state hot loops add only hits.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Buffers currently parked in the free-list.
    pub fn parked(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }

    /// Most buffers ever parked at once (see the field docs: bounded by
    /// the peak number of concurrent borrowers).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::Relaxed) as usize
    }
}

impl<T: Copy + Default + Send> Default for ScratchPool<T> {
    fn default() -> Self {
        ScratchPool::new()
    }
}

/// RAII handle to a pooled buffer; derefs to `[T]` and returns the
/// buffer to its pool on drop.
pub struct ScratchGuard<'a, T: Copy + Default + Send> {
    pool: &'a ScratchPool<T>,
    buf: Vec<T>,
}

impl<'a, T: Copy + Default + Send> Deref for ScratchGuard<'a, T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.buf
    }
}

impl<'a, T: Copy + Default + Send> DerefMut for ScratchGuard<'a, T> {
    fn deref_mut(&mut self) -> &mut [T] {
        &mut self.buf
    }
}

impl<'a, T: Copy + Default + Send> Drop for ScratchGuard<'a, T> {
    fn drop(&mut self) {
        self.pool.put(std::mem::take(&mut self.buf));
    }
}

static GLOBAL_F32: ScratchPool<f32> = ScratchPool::new();
static GLOBAL_F64: ScratchPool<f64> = ScratchPool::new();

/// Process-wide f32 scratch pool (state-width hot-path buffers).
pub fn global_f32() -> &'static ScratchPool<f32> {
    &GLOBAL_F32
}

/// Process-wide f64 scratch pool (small per-shard accumulators, e.g. the
/// GMM responsibilities).
pub fn global_f64() -> &'static ScratchPool<f64> {
    &GLOBAL_F64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_requested_length() {
        let p: ScratchPool<f32> = ScratchPool::new();
        let g = p.take(17);
        assert_eq!(g.len(), 17);
        let z = p.take_zeroed(5);
        assert!(z.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn buffers_are_reused_after_drop() {
        let p: ScratchPool<f32> = ScratchPool::new();
        {
            let mut g = p.take(100);
            g[0] = 1.0;
        } // returned here
        assert_eq!(p.parked(), 1);
        let _g2 = p.take(100);
        let (hits, misses) = p.stats();
        assert_eq!(hits, 1, "second take must be a pool hit");
        assert_eq!(misses, 1, "first take allocates");
        assert_eq!(p.parked(), 0);
    }

    #[test]
    fn best_fit_prefers_adequate_capacity() {
        let p: ScratchPool<f32> = ScratchPool::new();
        p.put(Vec::with_capacity(8));
        p.put(Vec::with_capacity(1024));
        let g = p.take(512); // must pick the 1024-cap buffer, not grow the 8
        assert!(g.buf.capacity() >= 1024);
        let (hits, misses) = p.stats();
        assert_eq!((hits, misses), (1, 0));
    }

    #[test]
    fn take_vec_put_roundtrip_is_allocation_free() {
        let p: ScratchPool<f32> = ScratchPool::new();
        let v = p.take_vec(64);
        p.put(v);
        for _ in 0..10 {
            let v = p.take_vec(64);
            p.put(v);
        }
        let (hits, misses) = p.stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 10);
    }

    #[test]
    fn pool_is_bounded() {
        let p: ScratchPool<f32> = ScratchPool::new();
        for _ in 0..(MAX_POOLED + 10) {
            p.put(vec![0.0f32; 4]);
        }
        assert_eq!(p.parked(), MAX_POOLED);
    }

    /// Concurrency stress: N threads × M iterations of borrow → mutate →
    /// drop with mixed widths.  Asserts the free-list loses no buffers
    /// (every take is accounted, buffers survive to be re-parked), the
    /// parked high-water mark never exceeds peak concurrency (a fresh
    /// buffer is only created when every existing one is live), and a
    /// live guard's contents are never visible to another live guard.
    #[test]
    fn concurrent_stress_borrow_mutate_drop() {
        const THREADS: usize = 8;
        const ITERS: usize = 400;
        let p: ScratchPool<f32> = ScratchPool::new();
        std::thread::scope(|s| {
            for tid in 0..THREADS {
                let p = &p;
                s.spawn(move || {
                    for it in 0..ITERS {
                        // mixed widths so best-fit churns the free-list
                        let len = 16 + (tid * 31 + it * 7) % 96;
                        let tag = (tid * ITERS + it) as f32 + 1.0;
                        let mut g = p.take(len);
                        assert_eq!(g.len(), len);
                        for v in g.iter_mut() {
                            *v = tag;
                        }
                        // while other guards are live and writing their
                        // own tags, ours must still be intact
                        assert!(
                            g.iter().all(|&v| v == tag),
                            "buffer shared across live guards (thread {tid}, iter {it})"
                        );
                    } // guard drops: buffer returns to the free-list
                });
            }
        });
        let (hits, misses) = p.stats();
        assert_eq!(hits + misses, (THREADS * ITERS) as u64, "every take accounted");
        // No lost buffers: all outstanding guards dropped, so everything
        // ever allocated is parked again...
        assert!(p.parked() >= 1);
        // ...and no buffer was conjured beyond peak concurrency: at most
        // one live guard per thread, so at most THREADS distinct buffers
        // can ever exist, parked or live.
        assert!(p.parked() <= THREADS, "parked {} > {THREADS} borrowers", p.parked());
        assert!(p.high_water() <= THREADS, "high water {} > {THREADS}", p.high_water());
        assert!(p.high_water() >= p.parked());
    }

    #[test]
    fn high_water_tracks_peak_parked() {
        let p: ScratchPool<f32> = ScratchPool::new();
        assert_eq!(p.high_water(), 0);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 4]);
        p.put(vec![0.0; 4]);
        assert_eq!(p.high_water(), 3);
        let _a = p.take(4);
        let _b = p.take(4);
        assert_eq!(p.parked(), 1);
        assert_eq!(p.high_water(), 3, "high water is a peak, not a level");
    }

    #[test]
    fn concurrent_takes_are_safe() {
        let p: ScratchPool<f32> = ScratchPool::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..200 {
                        let mut g = p.take(32);
                        g[31] = 1.0;
                    }
                });
            }
        });
        let (hits, misses) = p.stats();
        assert_eq!(hits + misses, 800);
        assert!(p.parked() <= 4);
    }
}
