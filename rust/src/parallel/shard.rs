//! Deterministic batch sharding, executed on the persistent worker pool.
//!
//! A shard is a contiguous range of batch *rows* of a flattened
//! `[batch, dim]` buffer.  The shard boundaries depend only on the row
//! count and the thread count — never on timing — and each worker writes
//! only its own rows, so a sharded loop produces bit-identical output to
//! its serial counterpart (same per-element operations in the same
//! order; sharding merely interleaves rows across cores).
//!
//! Execution lives in [`super::workers`]: [`run_shards`] hands the tasks
//! to the process-wide [`super::workers::WorkerPool`] instead of
//! spawning scoped threads per call, which is why the engagement grains
//! below are an order of magnitude lower than they were under
//! scoped-spawn dispatch.  The historical spawning path is kept as
//! [`run_shards_scoped`] so `benches/bench_workers.rs` can measure the
//! difference.

/// Environment knob for the worker count (`PALLAS_THREADS=4`).  Unset or
/// unparsable values fall back to the machine's available parallelism.
pub const THREADS_ENV: &str = "PALLAS_THREADS";

/// Minimum *work units* (≈ scalar float ops) per shard for compute-bound
/// per-row kernels before an extra thread is engaged.  ~4K f64 ops is a
/// couple of microseconds — a few multiples of one pool dispatch (the
/// barrier wake costs ~1–2µs; the scoped-thread spawn it replaced cost
/// ~10µs and forced this gate 8× higher).  Callers estimate work per row
/// (e.g. `components × dim` for the GMM score) and pass it to
/// [`heavy_shards`].
pub const HEAVY_GRAIN: usize = 1 << 12;

/// Minimum elements per shard for memory-bound elementwise loops (fused
/// accumulate/update: ~1 FLOP per element).  Larger than [`HEAVY_GRAIN`]
/// because a pool dispatch amortises only against tens of kilobytes of
/// streamed data — but 4× lower than under scoped spawning, so mid-size
/// batches shard too.
pub const LIGHT_GRAIN: usize = 1 << 14;

/// A contiguous range of batch rows assigned to one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// First row (inclusive).
    pub start: usize,
    /// Number of rows.
    pub len: usize,
}

fn parse_threads(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n >= 1)
}

/// Worker count: the `PALLAS_THREADS` override when set and valid, else
/// `std::thread::available_parallelism()`.  Read per call (not cached)
/// so tests and benches can flip the knob within one process; the
/// *pool* size is fixed at first use instead (see
/// [`super::workers::global`]) and absorbs larger counts by striding.
pub fn num_threads() -> usize {
    parse_threads(std::env::var(THREADS_ENV).ok().as_deref())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Deterministic partition of `rows` rows into at most `threads`
/// contiguous shards: the first `rows % threads` shards get one extra
/// row.  A pure function of its arguments — the shard→chunk assignment
/// never depends on scheduling.
pub fn shards(rows: usize, threads: usize) -> Vec<Shard> {
    let t = threads.clamp(1, rows.max(1));
    let base = rows / t;
    let extra = rows % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        out.push(Shard { start, len });
        start += len;
    }
    out
}

/// Pure core of [`heavy_shards`]/[`light_shards`]: partition `rows` rows
/// into at most `threads` shards such that **every shard carries at
/// least `grain` work units** (`work_per_row` each) unless it is the
/// only shard.  The guarantee is by construction: a shard must span at
/// least `⌈grain / work_per_row⌉` rows, so the shard count is capped at
/// `rows / ⌈grain / work_per_row⌉` before balancing.  Property-tested
/// below.
fn grain_shards_for(rows: usize, work_per_row: usize, grain: usize, threads: usize) -> Vec<Shard> {
    let cap = if work_per_row == 0 {
        1 // zero-work rows: sharding buys nothing
    } else {
        let min_rows = (grain.max(1) + work_per_row - 1) / work_per_row;
        rows / min_rows.max(1)
    };
    shards(rows, threads.min(cap.max(1)))
}

fn grain_shards(rows: usize, work_per_row: usize, grain: usize) -> Vec<Shard> {
    grain_shards_for(rows, work_per_row, grain, num_threads())
}

/// Shards for compute-bound per-row work: `work_per_row` is the caller's
/// estimate of scalar float ops per row, and every shard amounts to at
/// least [`HEAVY_GRAIN`] of them before an extra thread is engaged.
pub fn heavy_shards(rows: usize, work_per_row: usize) -> Vec<Shard> {
    grain_shards(rows, work_per_row, HEAVY_GRAIN)
}

/// Shards for memory-bound elementwise work (≥ [`LIGHT_GRAIN`] elements
/// per shard before an extra thread is engaged).
pub fn light_shards(rows: usize, dim: usize) -> Vec<Shard> {
    grain_shards(rows, dim, LIGHT_GRAIN)
}

/// Borrow each shard's rows of a shared `[batch, dim]` buffer.
pub fn split_rows<'a>(buf: &'a [f32], dim: usize, sh: &[Shard]) -> Vec<&'a [f32]> {
    sh.iter().map(|s| &buf[s.start * dim..(s.start + s.len) * dim]).collect()
}

/// Split a mutable `[batch, dim]` buffer into disjoint per-shard chunks.
/// The shards must tile the buffer contiguously from row 0 (which is
/// what [`shards`] produces).
pub fn split_rows_mut<'a>(buf: &'a mut [f32], dim: usize, sh: &[Shard]) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(sh.len());
    let mut rest = buf;
    let mut row = 0usize;
    for s in sh {
        assert_eq!(s.start, row, "shards must be contiguous from row 0");
        let (head, tail) = rest.split_at_mut(s.len * dim);
        out.push(head);
        rest = tail;
        row += s.len;
    }
    out
}

/// Run one task per shard on the persistent worker pool; the calling
/// thread takes the first task (so a single-task call is a plain inline
/// loop with zero synchronisation), parked workers take the rest, and
/// the call returns once every task has run.  Tasks typically carry the
/// disjoint `&mut` chunks produced by [`split_rows_mut`].  Semantics
/// (shard→task assignment, completion barrier) are identical to the
/// historical scoped-spawn version, minus the ~10µs/worker spawn cost —
/// see [`super::workers`] for the barrier protocol and
/// [`run_shards_scoped`] for the measured baseline.
pub fn run_shards<T, F>(tasks: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    if tasks.len() <= 1 {
        for (i, t) in tasks.into_iter().enumerate() {
            f(i, t);
        }
        return;
    }
    super::workers::global().run(tasks, f);
}

/// The pre-pool dispatch path: one scoped thread spawned per task beyond
/// the first, calling thread takes task 0.  Kept (not routed to by any
/// hot path) as the baseline `benches/bench_workers.rs` measures the
/// pool against, and as the reference semantics `run_shards` must match.
pub fn run_shards_scoped<T, F>(tasks: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    if tasks.len() <= 1 {
        for (i, t) in tasks.into_iter().enumerate() {
            f(i, t);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut iter = tasks.into_iter().enumerate();
        let first = iter.next();
        for (i, t) in iter {
            let fr = &f;
            s.spawn(move || fr(i, t));
        }
        if let Some((i, t)) = first {
            f(i, t);
        }
    });
}

/// Evaluate `f(shard, x_chunk, out_chunk)` over the given row shards —
/// the workhorse behind the parallel drift evaluations.  Serial when one
/// shard; bit-identical to serial always.
pub fn for_each_shard(
    x: &[f32],
    out: &mut [f32],
    dim: usize,
    sh: &[Shard],
    f: impl Fn(Shard, &[f32], &mut [f32]) + Sync,
) {
    debug_assert_eq!(x.len(), out.len(), "for_each_shard buffer size mismatch");
    if sh.len() <= 1 {
        let rows = if dim == 0 { 0 } else { x.len() / dim };
        let shard = sh.first().copied().unwrap_or(Shard { start: 0, len: rows });
        f(shard, x, out);
        return;
    }
    let xs = split_rows(x, dim, sh);
    let os = split_rows_mut(out, dim, sh);
    let tasks: Vec<(Shard, &[f32], &mut [f32])> =
        sh.iter().copied().zip(xs).zip(os).map(|((s, xc), oc)| (s, xc, oc)).collect();
    run_shards(tasks, |_, (s, xc, oc)| f(s, xc, oc));
}

/// [`for_each_shard`] over [`light_shards`] — for memory-bound
/// elementwise passes (adds, bumps, scalings).
pub fn par_map_rows_light(
    x: &[f32],
    out: &mut [f32],
    dim: usize,
    f: impl Fn(Shard, &[f32], &mut [f32]) + Sync,
) {
    let rows = if dim == 0 { 0 } else { x.len() / dim };
    for_each_shard(x, out, dim, &light_shards(rows, dim), f);
}

/// Minimum elements per shard for the sharded payload memcpy
/// ([`par_copy`]): far above [`LIGHT_GRAIN`] because a copy has no
/// compute to hide the dispatch behind, and a small copy queuing on the
/// pool's submit lock could stall behind an unrelated sampler kernel —
/// so only multi-megabyte payloads shard (4 MB of f32 per chunk).
pub const COPY_GRAIN: usize = 1 << 20;

/// Sharded memcpy for wide buffers (the executor's request payloads):
/// plain `copy_from_slice` below [`COPY_GRAIN`], pool-sharded chunks
/// above it.  A copy is trivially bit-identical however it is split.
/// `bench_workers` measures the sharded-vs-plain crossover.
pub fn par_copy(src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len(), "par_copy length mismatch");
    for_each_shard(src, dst, 1, &grain_shards(src.len(), 1, COPY_GRAIN), |_, s, d| {
        d.copy_from_slice(s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite as pt;

    #[test]
    fn shards_tile_exactly() {
        for rows in [0usize, 1, 2, 7, 64, 1000] {
            for t in [1usize, 2, 3, 4, 8, 33] {
                let sh = shards(rows, t);
                assert!(!sh.is_empty());
                assert!(sh.len() <= t.max(1));
                let mut row = 0;
                for s in &sh {
                    assert_eq!(s.start, row);
                    row += s.len;
                }
                assert_eq!(row, rows, "rows {rows} threads {t}");
                // balanced: sizes differ by at most one
                let min = sh.iter().map(|s| s.len).min().unwrap();
                let max = sh.iter().map(|s| s.len).max().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn shards_are_deterministic() {
        assert_eq!(shards(10, 3), shards(10, 3));
        assert_eq!(shards(10, 3)[0], Shard { start: 0, len: 4 });
        assert_eq!(shards(10, 3)[2], Shard { start: 7, len: 3 });
    }

    #[test]
    fn parse_threads_accepts_positive_integers_only() {
        assert_eq!(parse_threads(Some("4")), Some(4));
        assert_eq!(parse_threads(Some(" 2 ")), Some(2));
        assert_eq!(parse_threads(Some("0")), None);
        assert_eq!(parse_threads(Some("-1")), None);
        assert_eq!(parse_threads(Some("lots")), None);
        assert_eq!(parse_threads(None), None);
    }

    #[test]
    fn split_rows_mut_partitions_disjointly() {
        let dim = 3;
        let mut buf = vec![0.0f32; 10 * dim];
        let sh = shards(10, 4);
        let chunks = split_rows_mut(&mut buf, dim, &sh);
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total, 10 * dim);
        for (s, c) in sh.iter().zip(&chunks) {
            assert_eq!(c.len(), s.len * dim);
        }
    }

    #[test]
    fn run_shards_executes_every_task_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..9).map(|_| AtomicU64::new(0)).collect();
        let tasks: Vec<usize> = (0..9).collect();
        run_shards(tasks, |i, t| {
            assert_eq!(i, t);
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn run_shards_scoped_executes_every_task_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..9).map(|_| AtomicU64::new(0)).collect();
        let tasks: Vec<usize> = (0..9).collect();
        run_shards_scoped(tasks, |i, t| {
            assert_eq!(i, t);
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn for_each_shard_matches_serial_bitwise() {
        let dim = 5;
        let rows = 137;
        let x: Vec<f32> = (0..rows * dim).map(|i| (i as f32).sin()).collect();
        let kernel = |_s: Shard, xs: &[f32], os: &mut [f32]| {
            for (xb, ob) in xs.chunks_exact(dim).zip(os.chunks_exact_mut(dim)) {
                let dot: f32 = xb.iter().map(|&v| v * v).sum();
                for j in 0..dim {
                    ob[j] = xb[j] * dot.sqrt() + 1.0;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * dim];
        for_each_shard(&x, &mut serial, dim, &shards(rows, 1), kernel);
        for t in [2usize, 3, 7] {
            let mut par = vec![0.0f32; rows * dim];
            for_each_shard(&x, &mut par, dim, &shards(rows, t), kernel);
            assert!(
                serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads {t} diverged"
            );
        }
    }

    #[test]
    fn grain_caps_thread_count() {
        // tiny work never shards beyond one chunk
        let sh = grain_shards(4, 2, HEAVY_GRAIN);
        assert_eq!(sh.len(), 1);
    }

    #[test]
    fn par_copy_is_exact() {
        // spans both the serial (short) and sharded (wide) paths
        for len in [0usize, 5, 1000, 2 * COPY_GRAIN + 17] {
            let src: Vec<f32> = (0..len).map(|i| (i as f32).cos()).collect();
            let mut dst = vec![0.0f32; len];
            par_copy(&src, &mut dst);
            assert!(src.iter().zip(&dst).all(|(a, b)| a.to_bits() == b.to_bits()), "len {len}");
        }
    }

    /// Shared invariant checks for a grain-gated partition: covers every
    /// row exactly once in order, respects the thread cap, and never
    /// emits a shard below the grain unless it is the only shard.
    fn check_grain_invariants(
        sh: &[Shard],
        rows: usize,
        wpr: usize,
        grain: usize,
        threads: usize,
    ) -> Result<(), String> {
        if sh.is_empty() {
            return Err("empty shard list".into());
        }
        if sh.len() > threads.max(1) {
            return Err(format!("{} shards exceed {} threads", sh.len(), threads));
        }
        let mut row = 0usize;
        for s in sh {
            if s.start != row {
                return Err(format!("shard at {} expected to start at {row}", s.start));
            }
            row += s.len;
        }
        if row != rows {
            return Err(format!("shards cover {row} of {rows} rows"));
        }
        if sh.len() > 1 {
            for s in sh {
                if s.len * wpr < grain {
                    return Err(format!(
                        "shard of {} rows x {wpr} work < grain {grain} in a {}-shard split",
                        s.len,
                        sh.len()
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn grain_shards_property_invariants() {
        pt::check("grain_shards_invariants", 300, |gen| {
            let rows = gen.usize_range(0, 2000);
            let wpr = gen.usize_range(0, 5000);
            let grain = [1usize, 64, HEAVY_GRAIN, LIGHT_GRAIN][gen.usize_range(0, 4)];
            let threads = gen.usize_range(1, 64);
            let sh = grain_shards_for(rows, wpr, grain, threads);
            check_grain_invariants(&sh, rows, wpr, grain, threads).map_err(|e| {
                format!("rows {rows} wpr {wpr} grain {grain} threads {threads}: {e}")
            })?;
            // determinism: a pure function of its arguments
            if sh != grain_shards_for(rows, wpr, grain, threads) {
                return Err("non-deterministic partition".into());
            }
            Ok(())
        });
    }

    #[test]
    fn heavy_and_light_shards_satisfy_their_grains() {
        // The public wrappers read PALLAS_THREADS via num_threads();
        // whatever that returns, the invariants must hold against it.
        pt::check("heavy_light_shards_invariants", 200, |gen| {
            let rows = gen.usize_range(0, 1024);
            let t = num_threads();
            let wpr = gen.usize_range(1, 1 << 16);
            check_grain_invariants(&heavy_shards(rows, wpr), rows, wpr, HEAVY_GRAIN, t)
                .map_err(|e| format!("heavy rows {rows} wpr {wpr}: {e}"))?;
            let dim = gen.usize_range(1, 1024);
            check_grain_invariants(&light_shards(rows, dim), rows, dim, LIGHT_GRAIN, t)
                .map_err(|e| format!("light rows {rows} dim {dim}: {e}"))?;
            Ok(())
        });
    }

    #[test]
    fn grain_shards_engage_all_threads_when_work_allows() {
        // Plenty of work per row: the partition should use every thread.
        let sh = grain_shards_for(64, HEAVY_GRAIN, HEAVY_GRAIN, 8);
        assert_eq!(sh.len(), 8);
        // Exactly enough for two grains: no more than two shards.
        let sh = grain_shards_for(2, HEAVY_GRAIN, HEAVY_GRAIN, 8);
        assert_eq!(sh.len(), 2);
    }
}
