//! Deterministic batch sharding over scoped threads.
//!
//! A shard is a contiguous range of batch *rows* of a flattened
//! `[batch, dim]` buffer.  The shard boundaries depend only on the row
//! count and the thread count — never on timing — and each worker writes
//! only its own rows, so a sharded loop produces bit-identical output to
//! its serial counterpart (same per-element operations in the same
//! order; sharding merely interleaves rows across cores).

/// Environment knob for the worker count (`PALLAS_THREADS=4`).  Unset or
/// unparsable values fall back to the machine's available parallelism.
pub const THREADS_ENV: &str = "PALLAS_THREADS";

/// Minimum *work units* (≈ scalar float ops) per shard for compute-bound
/// per-row kernels before an extra thread is engaged.  ~32K f64 ops is
/// tens of microseconds — a few multiples of one thread spawn.  Callers
/// estimate work per row (e.g. `components × dim` for the GMM score) and
/// pass it to [`heavy_shards`].
pub const HEAVY_GRAIN: usize = 1 << 15;

/// Minimum elements per shard for memory-bound elementwise loops (fused
/// accumulate/update: ~1 FLOP per element).  Far larger than
/// [`HEAVY_GRAIN`] because a ~10µs thread spawn amortises only against
/// hundreds of kilobytes of streamed data.
pub const LIGHT_GRAIN: usize = 1 << 16;

/// A contiguous range of batch rows assigned to one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// First row (inclusive).
    pub start: usize,
    /// Number of rows.
    pub len: usize,
}

fn parse_threads(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n >= 1)
}

/// Worker count: the `PALLAS_THREADS` override when set and valid, else
/// `std::thread::available_parallelism()`.  Read per call (not cached)
/// so tests and benches can flip the knob within one process.
pub fn num_threads() -> usize {
    parse_threads(std::env::var(THREADS_ENV).ok().as_deref())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Deterministic partition of `rows` rows into at most `threads`
/// contiguous shards: the first `rows % threads` shards get one extra
/// row.  A pure function of its arguments — the shard→chunk assignment
/// never depends on scheduling.
pub fn shards(rows: usize, threads: usize) -> Vec<Shard> {
    let t = threads.clamp(1, rows.max(1));
    let base = rows / t;
    let extra = rows % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        out.push(Shard { start, len });
        start += len;
    }
    out
}

fn grain_shards(rows: usize, work_per_row: usize, grain: usize) -> Vec<Shard> {
    let cap = rows.saturating_mul(work_per_row) / grain.max(1);
    shards(rows, num_threads().min(cap.max(1)))
}

/// Shards for compute-bound per-row work: `work_per_row` is the caller's
/// estimate of scalar float ops per row, and a shard must amount to at
/// least [`HEAVY_GRAIN`] of them before an extra thread is engaged.
pub fn heavy_shards(rows: usize, work_per_row: usize) -> Vec<Shard> {
    grain_shards(rows, work_per_row, HEAVY_GRAIN)
}

/// Shards for memory-bound elementwise work (≥ [`LIGHT_GRAIN`] elements
/// per shard before an extra thread is engaged).
pub fn light_shards(rows: usize, dim: usize) -> Vec<Shard> {
    grain_shards(rows, dim, LIGHT_GRAIN)
}

/// Borrow each shard's rows of a shared `[batch, dim]` buffer.
pub fn split_rows<'a>(buf: &'a [f32], dim: usize, sh: &[Shard]) -> Vec<&'a [f32]> {
    sh.iter().map(|s| &buf[s.start * dim..(s.start + s.len) * dim]).collect()
}

/// Split a mutable `[batch, dim]` buffer into disjoint per-shard chunks.
/// The shards must tile the buffer contiguously from row 0 (which is
/// what [`shards`] produces).
pub fn split_rows_mut<'a>(buf: &'a mut [f32], dim: usize, sh: &[Shard]) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(sh.len());
    let mut rest = buf;
    let mut row = 0usize;
    for s in sh {
        assert_eq!(s.start, row, "shards must be contiguous from row 0");
        let (head, tail) = rest.split_at_mut(s.len * dim);
        out.push(head);
        rest = tail;
        row += s.len;
    }
    out
}

/// Run one task per shard on scoped threads; the calling thread takes
/// the first task, so a single-task call has zero thread overhead.
/// Tasks typically carry the disjoint `&mut` chunks produced by
/// [`split_rows_mut`].
pub fn run_shards<T, F>(tasks: Vec<T>, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    if tasks.len() <= 1 {
        for (i, t) in tasks.into_iter().enumerate() {
            f(i, t);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut iter = tasks.into_iter().enumerate();
        let first = iter.next();
        for (i, t) in iter {
            let fr = &f;
            s.spawn(move || fr(i, t));
        }
        if let Some((i, t)) = first {
            f(i, t);
        }
    });
}

/// Evaluate `f(shard, x_chunk, out_chunk)` over the given row shards —
/// the workhorse behind the parallel drift evaluations.  Serial when one
/// shard; bit-identical to serial always.
pub fn for_each_shard(
    x: &[f32],
    out: &mut [f32],
    dim: usize,
    sh: &[Shard],
    f: impl Fn(Shard, &[f32], &mut [f32]) + Sync,
) {
    debug_assert_eq!(x.len(), out.len(), "for_each_shard buffer size mismatch");
    if sh.len() <= 1 {
        let rows = if dim == 0 { 0 } else { x.len() / dim };
        let shard = sh.first().copied().unwrap_or(Shard { start: 0, len: rows });
        f(shard, x, out);
        return;
    }
    let xs = split_rows(x, dim, sh);
    let os = split_rows_mut(out, dim, sh);
    let tasks: Vec<(Shard, &[f32], &mut [f32])> =
        sh.iter().copied().zip(xs).zip(os).map(|((s, xc), oc)| (s, xc, oc)).collect();
    run_shards(tasks, |_, (s, xc, oc)| f(s, xc, oc));
}

/// [`for_each_shard`] over [`light_shards`] — for memory-bound
/// elementwise passes (adds, bumps, scalings).
pub fn par_map_rows_light(
    x: &[f32],
    out: &mut [f32],
    dim: usize,
    f: impl Fn(Shard, &[f32], &mut [f32]) + Sync,
) {
    let rows = if dim == 0 { 0 } else { x.len() / dim };
    for_each_shard(x, out, dim, &light_shards(rows, dim), f);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_tile_exactly() {
        for rows in [0usize, 1, 2, 7, 64, 1000] {
            for t in [1usize, 2, 3, 4, 8, 33] {
                let sh = shards(rows, t);
                assert!(!sh.is_empty());
                assert!(sh.len() <= t.max(1));
                let mut row = 0;
                for s in &sh {
                    assert_eq!(s.start, row);
                    row += s.len;
                }
                assert_eq!(row, rows, "rows {rows} threads {t}");
                // balanced: sizes differ by at most one
                let min = sh.iter().map(|s| s.len).min().unwrap();
                let max = sh.iter().map(|s| s.len).max().unwrap();
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn shards_are_deterministic() {
        assert_eq!(shards(10, 3), shards(10, 3));
        assert_eq!(shards(10, 3)[0], Shard { start: 0, len: 4 });
        assert_eq!(shards(10, 3)[2], Shard { start: 7, len: 3 });
    }

    #[test]
    fn parse_threads_accepts_positive_integers_only() {
        assert_eq!(parse_threads(Some("4")), Some(4));
        assert_eq!(parse_threads(Some(" 2 ")), Some(2));
        assert_eq!(parse_threads(Some("0")), None);
        assert_eq!(parse_threads(Some("-1")), None);
        assert_eq!(parse_threads(Some("lots")), None);
        assert_eq!(parse_threads(None), None);
    }

    #[test]
    fn split_rows_mut_partitions_disjointly() {
        let dim = 3;
        let mut buf = vec![0.0f32; 10 * dim];
        let sh = shards(10, 4);
        let chunks = split_rows_mut(&mut buf, dim, &sh);
        let total: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(total, 10 * dim);
        for (s, c) in sh.iter().zip(&chunks) {
            assert_eq!(c.len(), s.len * dim);
        }
    }

    #[test]
    fn run_shards_executes_every_task_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let hits: Vec<AtomicU64> = (0..9).map(|_| AtomicU64::new(0)).collect();
        let tasks: Vec<usize> = (0..9).collect();
        run_shards(tasks, |i, t| {
            assert_eq!(i, t);
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn for_each_shard_matches_serial_bitwise() {
        let dim = 5;
        let rows = 137;
        let x: Vec<f32> = (0..rows * dim).map(|i| (i as f32).sin()).collect();
        let kernel = |_s: Shard, xs: &[f32], os: &mut [f32]| {
            for (xb, ob) in xs.chunks_exact(dim).zip(os.chunks_exact_mut(dim)) {
                let dot: f32 = xb.iter().map(|&v| v * v).sum();
                for j in 0..dim {
                    ob[j] = xb[j] * dot.sqrt() + 1.0;
                }
            }
        };
        let mut serial = vec![0.0f32; rows * dim];
        for_each_shard(&x, &mut serial, dim, &shards(rows, 1), kernel);
        for t in [2usize, 3, 7] {
            let mut par = vec![0.0f32; rows * dim];
            for_each_shard(&x, &mut par, dim, &shards(rows, t), kernel);
            assert!(
                serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads {t} diverged"
            );
        }
    }

    #[test]
    fn grain_caps_thread_count() {
        // tiny work never shards beyond one chunk
        let sh = grain_shards(4, 2, HEAVY_GRAIN);
        assert_eq!(sh.len(), 1);
    }
}
