//! **mlem** — Multilevel Euler-Maruyama diffusion sampling and serving.
//!
//! Reproduction of *"Polynomial Speedup in Diffusion Models with the
//! Multilevel Euler-Maruyama Method"* (Jacot, 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3** (this crate) — the serving coordinator: request router,
//!   dynamic batcher with shared Bernoulli draws, ML-EM level scheduler,
//!   adaptive schedule learner, PJRT runtime, metrics.
//! * **L2/L1** (`python/compile`, build-time only) — the UNet score-model
//!   family and its Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! Python never runs on the request path: the binary loads HLO text via
//! the `xla` crate's PJRT CPU client and is self-contained thereafter
//! (an in-tree stub stands in when the `xla` feature is off).
//!
//! # Hot-path architecture
//!
//! The sampling hot path is **parallel and allocation-free** end to end:
//!
//! 1. [`parallel`] provides the substrate: a deterministic batch sharder
//!    (`[batch, dim]` rows split into contiguous [`parallel::Shard`]s,
//!    `PALLAS_THREADS` knob), the persistent [`parallel::WorkerPool`]
//!    (long-lived threads parked on an epoch barrier execute the shards —
//!    dispatch is a ~1–2µs wake instead of a ~10µs-per-worker scoped
//!    spawn, so the engagement grains are low enough for small batches to
//!    shard), and process-wide [`parallel::ScratchPool`]s whose buffers
//!    are recycled instead of reallocated.  Shard boundaries are a pure
//!    function of `(rows, threads)`, workers own disjoint rows, and the
//!    calling thread still takes shard 0, so every thread count — and
//!    pool vs. serial dispatch — produces **bit-identical** trajectories;
//!    verified by the `parity_parallel` property tests.
//! 2. The drift layer rides on it: the analytic GMM score
//!    ([`gmm::Gmm::score_t`]) and the Assumption-1 perturbation
//!    ([`gmm::PerturbedDrift`]) evaluate batch chunks in parallel, while
//!    [`sde::SumDrift`] and the central-difference `Drift::jvp` /
//!    `Denoiser::eps_jvp` defaults draw scratch from the pool instead of
//!    allocating per call.
//! 3. [`sde::mlem::mlem_sample`] fuses its accumulate and state-update
//!    loops per shard: the weighted level deltas, the Brownian increment
//!    and the Euler step stream through each cache line once per step,
//!    in fixed 8-lane f32 chunks ([`sde::mlem::kernels`]) that LLVM
//!    auto-vectorises — bit-identical to the scalar loops by
//!    construction.
//! 4. [`runtime`]'s executor ships request payloads in buffers from its
//!    own dedicated payload pool (so `ExecStats.pool_hits/misses` stay
//!    attributable to the request path even when samplers churn the
//!    global pools) and reuses one response channel per handle — no
//!    per-call channel or `to_vec` allocations on the request path.
//!    Concurrent eps/jvp jobs sharing `(level, bucket, t)` are fused
//!    executor-side into **one** padded-bucket device execute
//!    (cross-request micro-batching; `exec_linger_us`/`exec_max_group`
//!    knobs, bit-identical to singleton dispatch, measured by
//!    `bench_exec_batching` into `BENCH_exec_batching.json`).
//! 5. [`coordinator`]'s multi-lane runner pool keeps that grouping loop
//!    *fed*: `batch_workers` lanes pop batches of different
//!    compatibility classes off per-class queues concurrently
//!    (same-class batches stay serialized, so per-request bits are
//!    lane-count-independent), measured by `bench_coordinator` into
//!    `BENCH_coordinator.json`.
//! 6. [`runtime::Fleet`] spreads that work across `executors` device
//!    threads by **level affinity**: the costly top ladder level is
//!    pinned to one member while cheap levels pack onto the rest
//!    (cost-aware, calibrator-fed rebalance migrates homes at runtime
//!    after draining in-flight groups), so placement never changes a
//!    bit — measured by `bench_fleet` into `BENCH_fleet.json`.
//! 7. The saturation pass closes the loop: classes with equal step
//!    counts step behind [`coordinator::phase`]'s epoch barrier
//!    (`phase_align`), so their per-t jobs co-arrive in the executor's
//!    linger window *by construction*; a near-full class is briefly
//!    held when every lane is busy (`hold_budget_us`, bounded by the
//!    measured batch EWMA and any member's deadline headroom); and
//!    engine results come back in donated pool buffers, so a
//!    steady-state generate allocates no fresh output buffers
//!    (`ExecStats.out_pool_hits/misses`).  All three are timing/storage
//!    only — bit parity pinned by `tests/saturate_parity.rs`, gains
//!    measured by `bench_saturate` into `BENCH_saturate.json`.
//!
//! `cargo bench --bench bench_hotpath` tracks the resulting throughput
//! (serial vs parallel images/sec, pool allocations per step) in
//! `BENCH_hotpath.json` at the repo root; `cargo bench --bench
//! bench_workers` races the pool against the historical scoped-spawn
//! dispatch across batch sizes into `BENCH_workers.json`.
//!
//! Module map (see `DESIGN.md` for the full inventory):
//!
//! | module | role |
//! |---|---|
//! | [`util`] | dependency-free substrates: RNG, stats, JSON, duals, CLI, property tests, bench harness |
//! | [`parallel`] | batch sharder + persistent worker pool + scratch pools powering the hot path |
//! | [`sde`] | drift traits, noise schedule, EM / **ML-EM** samplers, DDPM/DDIM discretisations |
//! | [`gmm`] | analytic Gaussian-mixture substrate with constructed approximator ladders |
//! | [`levels`] | level-probability policies and cost accounting |
//! | [`adaptive`] | SGD learner for the time-dependent schedule (§3.1) |
//! | [`calibrate`] | online γ-calibration: streaming cost/error estimators, log–log γ̂ fit with drift detection, Theorem-1 autopilot |
//! | [`runtime`] | PJRT executable cache + neural drifts over the artifacts; executor-side cross-request micro-batching with donated payload/output pools; multi-executor fleet with level-affinity placement |
//! | [`coordinator`] | serving layer: server, per-class batcher, multi-lane runner pool with lane-aware batch holding, cross-class phase barrier (`phase`), scheduler |
//! | [`trace`] | flight recorder: sampled end-to-end span tracing (per-thread rings, per-(level, t) attribution, Chrome-trace export) |
//! | [`benchgate`] | CI bench-regression gate over the `BENCH_*.json` artifacts |

// Kernel-style indexed loops are the idiom throughout this crate: they
// mirror the paper's math and keep the serial and sharded variants of
// each loop visibly identical (the bit-parity contract).  The clippy
// range-loop and argument-count lints fight that idiom.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod util {
    //! Dependency-free substrates (offline build: no serde/rand/clap/...).
    pub mod bench;
    pub mod cli;
    pub mod dual;
    pub mod json;
    pub mod proptest_lite;
    pub mod rng;
    pub mod stats;
}

pub mod adaptive;
pub mod benchgate;
pub mod benchkit;
pub mod calibrate;
pub mod config;
pub mod coordinator;
pub mod gmm;
pub mod levels;
pub mod metrics;
pub mod parallel;
pub mod runtime;
pub mod sde;
pub mod trace;
