//! **mlem** — Multilevel Euler-Maruyama diffusion sampling and serving.
//!
//! Reproduction of *"Polynomial Speedup in Diffusion Models with the
//! Multilevel Euler-Maruyama Method"* (Jacot, 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3** (this crate) — the serving coordinator: request router,
//!   dynamic batcher with shared Bernoulli draws, ML-EM level scheduler,
//!   adaptive schedule learner, PJRT runtime, metrics.
//! * **L2/L1** (`python/compile`, build-time only) — the UNet score-model
//!   family and its Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! Python never runs on the request path: the binary loads HLO text via
//! the `xla` crate's PJRT CPU client and is self-contained thereafter.
//!
//! Module map (see `DESIGN.md` for the full inventory):
//!
//! | module | role |
//! |---|---|
//! | [`util`] | dependency-free substrates: RNG, stats, JSON, duals, CLI, property tests, bench harness |
//! | [`sde`] | drift traits, noise schedule, EM / **ML-EM** samplers, DDPM/DDIM discretisations |
//! | [`gmm`] | analytic Gaussian-mixture substrate with constructed approximator ladders |
//! | [`levels`] | level-probability policies and cost accounting |
//! | [`adaptive`] | SGD learner for the time-dependent schedule (§3.1) |
//! | [`runtime`] | PJRT executable cache + neural drifts over the artifacts |
//! | [`coordinator`] | serving layer: server, batcher, scheduler, state |

pub mod util {
    //! Dependency-free substrates (offline build: no serde/rand/clap/...).
    pub mod bench;
    pub mod cli;
    pub mod dual;
    pub mod json;
    pub mod proptest_lite;
    pub mod rng;
    pub mod stats;
}

pub mod adaptive;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod gmm;
pub mod levels;
pub mod metrics;
pub mod runtime;
pub mod sde;
