//! End-to-end serving driver (the DESIGN.md validation run).
//!
//! Starts the full coordinator on an ephemeral TCP port, loads the real
//! trained model family through PJRT, then drives it with concurrent
//! client load: a mix of ML-EM and EM generation requests across several
//! connections.  Reports throughput and latency percentiles plus the
//! server's own metrics snapshot.  Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e [-- --requests 40]
//! ```

// The spawn_executor* wrappers used below are #[deprecated] veneers
// over runtime::ExecutorBuilder (PR 9); this file keeps calling them
// on purpose, doubling as their compatibility coverage.
#![allow(deprecated)]
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::time::Instant;

use anyhow::Result;

use mlem::config::ServeConfig;
use mlem::coordinator::{Scheduler, Server};
use mlem::metrics::Metrics;
use mlem::runtime::{spawn_executor, Manifest};
use mlem::util::cli::Args;
use mlem::util::json::Json;
use mlem::util::stats;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize_or("requests", 40);
    let n_clients = args.usize_or("clients", 4);
    let steps = args.usize_or("steps", 100);
    let images_per_req = args.usize_or("n", 4);

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        max_batch: 16,
        max_wait_ms: 5,
        cost_reps: 3,
        ..Default::default()
    };
    let manifest = Manifest::load(&cfg.artifacts)?;
    let metrics = Metrics::new();
    let (handle, _join) = spawn_executor(manifest, Some(metrics.clone()))?;
    let scheduler = Scheduler::new(handle.clone(), cfg.clone(), metrics.clone())?;
    println!("per-level costs (s/img): {:?}", scheduler.costs);

    let server = std::sync::Arc::new(Server::new(cfg, scheduler));
    let (addr_tx, addr_rx) = channel();
    let srv = server.clone();
    let server_thread =
        std::thread::spawn(move || srv.run(move |a| addr_tx.send(a).unwrap()).unwrap());
    let addr = addr_rx.recv()?;
    println!("server up on {addr}; driving {n_requests} requests from {n_clients} clients\n");

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let per_client = n_requests / n_clients;
        joins.push(std::thread::spawn(move || -> Vec<(f64, f64, f64)> {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let mut out = Vec::new();
            for i in 0..per_client {
                // alternate ML-EM and EM so both paths carry load
                let sampler = if i % 4 == 3 { "em" } else { "mlem" };
                let req = format!(
                    r#"{{"cmd":"generate","n":{images_per_req},"sampler":"{sampler}","steps":{steps},"seed":{}}}"#,
                    c * 1000 + i
                );
                let t = Instant::now();
                writeln!(writer, "{req}").unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let wall = t.elapsed().as_secs_f64() * 1e3;
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
                let q = j.get_path(&["stats", "queue_ms"]).unwrap().as_f64().unwrap();
                let b = j.get_path(&["stats", "batch_size"]).unwrap().as_f64().unwrap();
                out.push((wall, q, b));
            }
            out
        }));
    }
    let mut lat = Vec::new();
    let mut queue = Vec::new();
    let mut batch = Vec::new();
    for j in joins {
        for (w, q, b) in j.join().unwrap() {
            lat.push(w);
            queue.push(q);
            batch.push(b);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let total_images = (lat.len() * images_per_req) as f64;

    println!("== serve_e2e results ==");
    println!("requests completed   : {}", lat.len());
    println!("wallclock            : {wall:.2} s");
    println!("throughput           : {:.1} images/s ({:.1} req/s)", total_images / wall, lat.len() as f64 / wall);
    println!(
        "request latency (ms) : p50 {:.0}  p95 {:.0}  max {:.0}",
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 95.0),
        stats::percentile(&lat, 100.0)
    );
    println!(
        "queue wait (ms)      : p50 {:.1}  p95 {:.1}",
        stats::percentile(&queue, 50.0),
        stats::percentile(&queue, 95.0)
    );
    println!("mean batch size      : {:.2} images", stats::mean(&batch));
    println!("\nserver metrics: {}", metrics.snapshot());

    // clean shutdown through the protocol
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writeln!(writer, r#"{{"cmd":"shutdown"}}"#)?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    server_thread.join().unwrap();
    handle.stop();
    Ok(())
}
