//! The Theorem-1 story on the analytic GMM substrate: ML-EM reaches the
//! same pathwise error as EM at a fraction of the (constructed,
//! Assumption-1) compute cost, and the advantage grows as the target
//! error shrinks — the polynomial speedup.
//!
//! ```bash
//! cargo run --release --example analytic_speedup
//! ```

use mlem::gmm::{assumption1_family, Gmm, LangevinDrift};
use mlem::levels::{theory_probs, Policy};
use mlem::sde::drift::Drift;
use mlem::sde::em::{em_sample, TimeGrid};
use mlem::sde::mlem::{mlem_sample, BernoulliMode, MlemFamily};
use mlem::sde::BrownianPath;
use mlem::util::bench::Table;
use mlem::util::rng::Rng;
use mlem::util::stats;

fn main() {
    let gamma = 2.5; // the paper's measured CelebA value
    let dim = 8;
    let gmm = Gmm::random(11, 4, dim, 2.0, 0.5);
    let exact = LangevinDrift { gmm: &gmm };

    // Assumption-1 ladder: errors 2^-2 .. 2^-7, costs (2^k)^gamma.
    let fam_drifts = assumption1_family(&exact, 2, 6, 1.0, gamma, 33);
    let costs: Vec<f64> = fam_drifts.iter().map(|d| d.cost()).collect();
    println!("constructed family: errors 2^-2..2^-7, costs {costs:?}\n");

    let batch = 16;
    let steps = 400;
    let span = 2.0;
    let grid = TimeGrid::new(span, 0.0, steps);
    let mut rng = Rng::new(5);
    let path = BrownianPath::sample(&mut rng, steps, batch * dim, span);
    let x0: Vec<f32> = (0..batch * dim).map(|_| rng.normal_f32() * 2.0).collect();

    // Reference: EM with the exact drift.
    let mut x_ref = x0.clone();
    em_sample(&exact, |_| (2.0f64).sqrt(), &mut x_ref, &grid, &path);

    let mut table = Table::new(
        "analytic speedup (gamma=2.5, Langevin GMM)",
        &["method", "rmse_vs_exact", "cost_units", "evals/level"],
    );

    // EM with each single level: cost = steps * cost_k.
    for (i, lvl) in fam_drifts.iter().enumerate() {
        let mut x = x0.clone();
        em_sample(lvl, |_| (2.0f64).sqrt(), &mut x, &grid, &path);
        let rmse = stats::mse_f32(&x, &x_ref).sqrt();
        table.row(&[
            format!("EM f^{}", i + 1),
            format!("{rmse:.5}"),
            format!("{:.0}", steps as f64 * batch as f64 * costs[i]),
            format!("{steps}@{}", i + 1),
        ]);
    }

    // ML-EM with theory probabilities at several cost scales.
    let fam = MlemFamily {
        base: None,
        levels: fam_drifts.iter().map(|d| d as &dyn Drift).collect(),
    };
    for scale in [1.0, 4.0, 16.0] {
        let base_policy = theory_probs(scale, gamma, 0, (fam_drifts.len() - 1) as i64);
        let policy = match &base_policy {
            Policy::Manual { probs } => Policy::Manual { probs: probs.clone() },
            _ => unreachable!(),
        };
        // best-of-5 over Bernoulli draws (the paper's best-of-15, scaled)
        let mut best: Option<(f64, mlem::sde::SampleReport)> = None;
        for seed in 0..5 {
            let mut x = x0.clone();
            let mut bern = Rng::new(100 + seed);
            let rep = mlem_sample(
                &fam,
                &policy,
                BernoulliMode::Shared,
                |_| (2.0f64).sqrt(),
                &mut x,
                batch,
                &grid,
                &path,
                &mut bern,
            );
            let rmse = stats::mse_f32(&x, &x_ref).sqrt();
            if best.as_ref().map_or(true, |(b, _)| rmse < *b) {
                best = Some((rmse, rep));
            }
        }
        let (rmse, rep) = best.unwrap();
        table.row(&[
            format!("ML-EM C={scale}"),
            format!("{rmse:.5}"),
            format!("{:.0}", rep.cost_units),
            format!("{:?}", rep.batch_evals),
        ]);
    }
    table.emit();

    println!(
        "Reading: ML-EM rows should reach the error of the *expensive* EM rows\n\
         at a small multiple of the *cheap* EM rows' cost — the paper's point.\n\
         (Costs are Assumption-1 units: cost(f^k) = 2^(gamma k).)"
    );
}
