//! Quickstart: load the AOT artifacts, generate a few images with ML-EM,
//! compare against plain EM on cost, and dump a PGM strip.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

// The spawn_executor* wrappers used below are #[deprecated] veneers
// over runtime::ExecutorBuilder (PR 9); this file keeps calling them
// on purpose, doubling as their compatibility coverage.
#![allow(deprecated)]
use anyhow::Result;

use mlem::config::{SamplerKind, ServeConfig};
use mlem::coordinator::protocol::{GenRequest, PolicyChoice};
use mlem::coordinator::Scheduler;
use mlem::metrics::Metrics;
use mlem::runtime::{spawn_executor, Manifest};

fn main() -> Result<()> {
    let cfg = ServeConfig { cost_reps: 3, ..Default::default() };
    let manifest = Manifest::load(&cfg.artifacts)?;
    println!(
        "loaded manifest: {} levels, {}x{} images, buckets {:?}",
        manifest.num_levels(),
        manifest.img,
        manifest.img,
        manifest.batch_buckets
    );
    let metrics = Metrics::new();
    let (handle, _join) = spawn_executor(manifest, Some(metrics.clone()))?;
    let scheduler = Scheduler::new(handle.clone(), cfg, metrics)?;
    println!("measured per-image costs (s): {:?}", scheduler.costs);

    // ML-EM generation: mostly f^1 evals, occasional f^3/f^5 corrections.
    let mut req = GenRequest {
        n: 8,
        sampler: SamplerKind::Mlem,
        steps: 200,
        seed: 7,
        levels: vec![1, 3, 5],
        delta: 0.0,
        policy: PolicyChoice::Default,
        return_images: true,
    };
    let mlem_resp = scheduler.generate(&req)?;
    println!(
        "ML-EM: {} images, {:.0} ms, nfe per level {:?}",
        req.n, mlem_resp.stats.wall_ms, mlem_resp.stats.nfe
    );

    // Baseline: plain EM with the largest network every step.
    req.sampler = SamplerKind::Em;
    let em_resp = scheduler.generate(&req)?;
    println!(
        "EM(f^5): {} images, {:.0} ms, nfe per level {:?}",
        req.n, em_resp.stats.wall_ms, em_resp.stats.nfe
    );
    println!(
        "speedup at equal steps: {:.2}x wallclock, {:.2}x cost units",
        em_resp.stats.wall_ms / mlem_resp.stats.wall_ms,
        em_resp.stats.cost_units / mlem_resp.stats.cost_units
    );

    // Dump the ML-EM images for eyeballing.
    let imgs = mlem_resp.images.unwrap();
    let img = scheduler.handle().manifest().img;
    write_pgm("quickstart_mlem.pgm", &imgs, img, 8)?;
    println!("wrote quickstart_mlem.pgm ({}x{} strip)", img * 8, img);

    handle.stop();
    Ok(())
}

fn write_pgm(path: &str, imgs: &[f32], img: usize, n: usize) -> Result<()> {
    let w = img * n;
    let mut data = Vec::with_capacity(w * img);
    for row in 0..img {
        for i in 0..n {
            for col in 0..img {
                let v = imgs[i * img * img + row * img + col];
                data.push((((v + 1.0) / 2.0).clamp(0.0, 1.0) * 255.0) as u8);
            }
        }
    }
    let mut out = format!("P5\n{w} {img}\n255\n").into_bytes();
    out.extend_from_slice(&data);
    std::fs::write(path, out)?;
    Ok(())
}
