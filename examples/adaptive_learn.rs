//! §3.1 adaptive method over the real trained family: learn α_k, β_k by
//! SGD (score-function + forward gradients, JVPs served from the AOT
//! jvp artifacts) and show the learned schedule beating the fixed one on
//! the error/cost frontier.
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptive_learn [-- --iters 25]
//! ```

// The spawn_executor* wrappers used below are #[deprecated] veneers
// over runtime::ExecutorBuilder (PR 9); this file keeps calling them
// on purpose, doubling as their compatibility coverage.
#![allow(deprecated)]
use anyhow::Result;

use mlem::adaptive::{Learner, LearnerConfig, Schedule};
use mlem::runtime::{spawn_executor, Manifest, NeuralDenoiser};
use mlem::sde::drift::{DiffusionDrift, Drift, LinearPartDrift, ScorePartDrift};
use mlem::sde::em::{em_sample, TimeGrid};
use mlem::sde::mlem::{mlem_sample, BernoulliMode, MlemFamily};
use mlem::sde::{schedule, BrownianPath};
use mlem::util::cli::Args;
use mlem::util::rng::Rng;
use mlem::util::stats;

fn main() -> Result<()> {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 25);
    let steps = args.usize_or("steps", 40);
    let lambda = args.f64_or("lambda", 0.1); // the paper's DDPM value

    let manifest = Manifest::load(&args.str_or("artifacts", "artifacts"))?;
    let dim = manifest.dim;
    let (handle, _join) = spawn_executor(manifest, None)?;
    let denoisers = NeuralDenoiser::family(&handle, 2)?;

    // Family {f^1, f^3, f^5} as in the paper's experiments.
    let base = LinearPartDrift { dim };
    let l1 = ScorePartDrift { den: &denoisers[0], ode: false };
    let l3 = ScorePartDrift { den: &denoisers[2], ode: false };
    let l5 = ScorePartDrift { den: &denoisers[4], ode: false };
    let fam = MlemFamily { base: Some(&base), levels: vec![&l1 as &dyn Drift, &l3, &l5] };
    let reference = DiffusionDrift::sde(&denoisers[4]);
    // costs in milliseconds so lambda has a sane scale
    let costs: Vec<f64> = [&l1 as &dyn Drift, &l3, &l5].iter().map(|d| d.cost() * 1e3).collect();
    println!("level costs (ms/img): {costs:?}");

    let learner = Learner {
        family: &fam,
        reference: &reference,
        costs: costs.clone(),
        cfg: LearnerConfig {
            lambda,
            steps,
            t_start: schedule::T_MAX,
            t_end: schedule::T_MIN,
            lr: 0.02,
            batch: 8,
            ode: false,
            clip: 0.25,
        },
    };

    // Start from the fixed inverse-cost probabilities.
    let p0: Vec<f64> = costs.iter().map(|c| (2.0 * costs[0] / c).min(0.999)).collect();
    let mut sched = Schedule::from_probs(&p0, 0.1);
    println!("initial probs at t=0.5: {:?}", probe(&sched));

    let mut rng = Rng::new(1);
    let trace = learner.fit(&mut sched, iters, &mut rng);
    for (i, (loss, cost)) in trace.iter().enumerate() {
        if i % 5 == 0 || i == trace.len() - 1 {
            println!("iter {i:3}: loss {loss:.4}  cost {cost:.1}  objective {:.4}", loss + lambda * cost);
        }
    }
    println!("learned alpha: {:?}", sched.alpha.iter().map(|a| format!("{a:.2}")).collect::<Vec<_>>());
    println!("learned beta : {:?}", sched.beta.iter().map(|b| format!("{b:.2}")).collect::<Vec<_>>());
    println!("learned probs at t=0.9/0.5/0.1: {:?} / {:?} / {:?}", probe_at(&sched, 0.9), probe_at(&sched, 0.5), probe_at(&sched, 0.1));

    // Evaluate fixed vs learned on a held-out generation (same noise).
    let batch = 8;
    let eval_steps = 120;
    let grid = TimeGrid::new(schedule::T_MAX, schedule::T_MIN, eval_steps);
    let mut eval_rng = Rng::new(77);
    let path = BrownianPath::sample(&mut eval_rng, eval_steps, batch * dim, grid.span());
    let x0: Vec<f32> = (0..batch * dim).map(|_| eval_rng.normal_f32()).collect();
    let mut x_true = x0.clone();
    em_sample(&reference, |t| schedule::beta(t).sqrt(), &mut x_true, &grid, &path);

    for (name, policy) in [
        ("fixed inv-cost", Schedule::from_probs(&p0, 0.1).policy()),
        ("learned", sched.policy()),
    ] {
        let mut best = f64::INFINITY;
        let mut best_cost = 0.0;
        for seed in 0..5 {
            let mut x = x0.clone();
            let mut bern = Rng::new(300 + seed);
            let rep = mlem_sample(
                &fam,
                &policy,
                BernoulliMode::Shared,
                |t| schedule::beta(t).sqrt(),
                &mut x,
                batch,
                &grid,
                &path,
                &mut bern,
            );
            let mse = stats::mse_f32(&x, &x_true);
            if mse < best {
                best = mse;
                best_cost = rep.cost_units;
            }
        }
        println!("{name:16}: best-of-5 MSE {best:.5} at cost {best_cost:.3}");
    }
    handle.stop();
    Ok(())
}

fn probe(s: &Schedule) -> Vec<String> {
    probe_at(s, 0.5)
}

fn probe_at(s: &Schedule, t: f64) -> Vec<String> {
    (0..s.num_levels()).map(|k| format!("{:.3}", s.prob(k, t))).collect()
}
